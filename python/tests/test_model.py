"""L2 model tests: manifests, mask semantics, policy plumbing, Pallas parity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SPEC = M.VARIANTS["micro"]
RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def setup():
    params = [jnp.asarray(p) for p in M.init_params(SPEC, seed=1)]
    policy = [jnp.asarray(p) for p in M.identity_policy(SPEC)]
    x = jnp.asarray(RNG.normal(size=(4, 32, 32, 3)).astype(np.float32))
    return params, policy, x


def _qidx(name):
    return {m["name"]: i for i, m in enumerate(M.policy_manifest(SPEC))}[name]


def test_manifest_shapes_consistent():
    for variant, spec in M.VARIANTS.items():
        pm = M.param_manifest(spec)
        params = M.init_params(spec)
        assert len(pm) == len(params)
        for m, p in zip(pm, params):
            assert tuple(m["shape"]) == p.shape, (variant, m["name"])


def test_conv_specs_topology_resnet18():
    convs, fc = M.conv_specs(M.VARIANTS["resnet18s"])
    assert len(convs) == 1 + 16 + 3  # stem + 8 blocks x 2 convs + 3 downsample
    assert fc.cin == 256 and fc.cout == 10
    # dependency groups: stage streams
    for c in convs:
        if c.name.endswith(".conv2") or c.name.endswith(".down") or c.name == "stem":
            assert c.group >= 0 and not c.prunable
        else:
            assert c.prunable and c.group == -1
    # all group members share the stream width
    by_group = {}
    for c in convs:
        if c.group >= 0:
            by_group.setdefault(c.group, set()).add(c.cout)
    assert all(len(widths) == 1 for widths in by_group.values())


def test_forward_shape(setup):
    params, policy, x = setup
    logits = M.forward(SPEC, params, policy, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_identity_policy_is_reference(setup):
    """bits=0 masks=1 must be the plain uncompressed network."""
    params, policy, x = setup
    a = M.forward(SPEC, params, policy, x)
    b = M.forward(SPEC, params, policy, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mask_equals_structural_removal(setup):
    """Masking conv1 output channels == removing them (zero contribution)."""
    params, policy, x = setup
    pol = list(policy)
    i = _qidx("s2b0.conv1.mask")
    mask = np.ones(32, np.float32)
    mask[8:] = 0.0
    pol[i] = jnp.asarray(mask)
    masked = M.forward(SPEC, params, pol, x)

    # physically zero the pruned channels' weights AND downstream consumers
    pidx = {m["name"]: i for i, m in enumerate(M.param_manifest(SPEC))}
    params2 = list(params)
    w = np.asarray(params2[pidx["s2b0.conv1.w"]]).copy()
    w[..., 8:] = 0
    params2[pidx["s2b0.conv1.w"]] = jnp.asarray(w)
    # BN on zeroed channels gives beta - mean*inv != 0, so masking is still
    # required; with the mask in place both must agree exactly.
    structural = M.forward(SPEC, params2, pol, x)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(structural),
                               rtol=1e-5, atol=1e-5)


def test_quantization_changes_output(setup):
    params, policy, x = setup
    pol = list(policy)
    pol[_qidx("s1b0.conv1.w_bits")] = jnp.asarray(2.0)
    a = M.forward(SPEC, params, policy, x)
    b = M.forward(SPEC, params, pol, x)
    assert float(jnp.abs(a - b).max()) > 1e-6


def test_stronger_quant_more_distortion(setup):
    params, policy, x = setup
    ref = M.forward(SPEC, params, policy, x)
    dists = []
    for bits in [8.0, 4.0, 2.0, 1.0]:
        pol = list(policy)
        for i, m in enumerate(M.policy_manifest(SPEC)):
            if m["name"].endswith("bits"):
                pol[i] = jnp.asarray(bits)
        out = M.forward(SPEC, params, pol, x)
        dists.append(float(jnp.abs(out - ref).mean()))
    assert dists[0] < dists[2] and dists[1] < dists[3]


def test_pallas_matches_xla_fp32(setup):
    """With quantization bypassed the Pallas path must equal the XLA path."""
    params, policy, x = setup
    a = M.forward(SPEC, params, policy, x, use_pallas=False)
    b = M.forward(SPEC, params, policy, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_pallas_quantized_close(setup):
    """Quantized Pallas path differs only by activation-calibration
    granularity (per-tensor post-im2col vs per-channel) — outputs stay close
    and the predicted classes mostly agree."""
    params, policy, x = setup
    pol = list(policy)
    for i, m in enumerate(M.policy_manifest(SPEC)):
        if m["name"].endswith("bits"):
            pol[i] = jnp.asarray(8.0)
    a = M.forward(SPEC, params, pol, x, use_pallas=False)
    b = M.forward(SPEC, params, pol, x, use_pallas=True)
    assert float(jnp.abs(a - b).mean()) < 0.25 * float(jnp.abs(a).mean()) + 0.1


def test_train_step_reduces_loss(setup):
    params, policy, _ = setup
    x = jnp.asarray(RNG.normal(size=(16, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray((np.arange(16) % 10).astype(np.int32))
    tidx = M.trainable_indices(SPEC)
    moms = [jnp.zeros_like(params[i]) for i in tidx]
    l0 = float(M.loss_fn(SPEC, params, policy, x, y))
    cur = list(params)
    for _ in range(5):
        loss, new_t, moms = M.train_step(SPEC, cur, moms, policy, x, y, jnp.float32(0.05))
        for j, i in enumerate(tidx):
            cur[i] = new_t[j]
    l1 = float(M.loss_fn(SPEC, cur, policy, x, y))
    assert l1 < l0


def test_policy_manifest_order():
    qm = M.policy_manifest(SPEC)
    convs, _ = M.conv_specs(SPEC)
    assert len(qm) == 3 * len(convs) + 2
    assert qm[0]["name"] == "stem.mask"
    assert qm[-1]["name"] == "fc.a_bits"


def test_manifest_json_roundtrip():
    import json
    man = M.manifest(M.VARIANTS["resnet18s"])
    s = json.dumps(man)
    back = json.loads(s)
    assert back["layers"][0]["name"] == "stem"
    assert back["layers"][-1]["kind"] == "linear"
    assert len(back["params"]) == len(M.param_manifest(M.VARIANTS["resnet18s"]))
