"""Unit tests for the Eq. 3 fake-quantizer with runtime bit widths."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant

RNG = np.random.default_rng(1)


def test_bypass_bits_zero():
    x = jnp.asarray(RNG.normal(size=(8, 8)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(quant.fake_quant(x, jnp.float32(0.0))),
                                  np.asarray(x))


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 6, 8])
def test_level_count_bounded(bits):
    x = jnp.asarray(RNG.normal(size=(4096,)).astype(np.float32))
    fq = np.asarray(quant.fake_quant(x, jnp.float32(bits), axis=None))
    assert len(np.unique(fq.round(6))) <= 2 ** (bits + 1)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_error_bounded_by_step(bits):
    x = RNG.uniform(-3, 3, size=(2048,)).astype(np.float32)
    fq = np.asarray(quant.fake_quant(jnp.asarray(x), jnp.float32(bits), axis=None))
    n = 2 ** bits - 1
    step = (x.max() - x.min()) / n
    assert np.abs(fq - x).max() <= step * 1.5 + 1e-6


def test_per_channel_axis():
    """Each channel is calibrated independently on its own range."""
    x = np.stack([RNG.normal(0, 1, 256), RNG.normal(0, 100, 256)], axis=1).astype(np.float32)
    fq = np.asarray(quant.fake_quant(jnp.asarray(x), jnp.float32(4), axis=1))
    # wide channel's error is ~100x the narrow channel's, not shared
    e0 = np.abs(fq[:, 0] - x[:, 0]).max()
    e1 = np.abs(fq[:, 1] - x[:, 1]).max()
    assert e1 > 10 * e0


def test_constant_tensor_stable():
    x = jnp.full((16,), 3.25, jnp.float32)
    fq = np.asarray(quant.fake_quant(x, jnp.float32(8), axis=None))
    assert np.all(np.isfinite(fq))


def test_ste_gradient_is_identity():
    x = jnp.asarray(RNG.normal(size=(32,)).astype(np.float32))
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant_ste(v, jnp.float32(3), axis=None) ** 2))(x)
    # d/dx sum(fq(x)^2) under STE = 2*fq(x)
    fq = quant.fake_quant(x, jnp.float32(3), axis=None)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(fq), rtol=1e-5)


def test_quantize_integer_levels():
    x = jnp.asarray(RNG.normal(size=(128,)).astype(np.float32))
    q, s, z = quant.quantize(x, jnp.float32(5), axis=None)
    qn = np.asarray(q)
    assert np.all(qn == np.floor(qn))
    assert np.abs(qn).max() <= 2 ** 5 - 1


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]),
       n=st.integers(2, 512), seed=st.integers(0, 2 ** 16),
       scale=st.floats(0.01, 100.0))
def test_fake_quant_hypothesis(bits, n, seed, scale):
    x = (np.random.default_rng(seed).normal(size=(n,)) * scale).astype(np.float32)
    fq = np.asarray(quant.fake_quant(jnp.asarray(x), jnp.float32(bits), axis=None))
    assert np.all(np.isfinite(fq))
    nlevels = 2 ** int(bits) - 1
    if x.max() > x.min():
        step = (x.max() - x.min()) / nlevels
        assert np.abs(fq - x).max() <= 2.0 * step + 1e-5
