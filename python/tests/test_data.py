"""Synthetic dataset: determinism, balance, value ranges, separability."""
from __future__ import annotations

import numpy as np

from compile import data as D


def test_deterministic():
    a_x, a_y = D.make_dataset(64, seed=3)
    b_x, b_y = D.make_dataset(64, seed=3)
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)


def test_seed_changes_data():
    a_x, _ = D.make_dataset(64, seed=3)
    b_x, _ = D.make_dataset(64, seed=4)
    assert np.abs(a_x - b_x).max() > 0.1


def test_shapes_and_range():
    x, y = D.make_dataset(50, seed=0)
    assert x.shape == (50, 32, 32, 3) and x.dtype == np.float32
    assert y.shape == (50,) and y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))


def test_class_balance():
    _, y = D.make_dataset(1000, seed=1)
    counts = np.bincount(y, minlength=10)
    assert counts.max() - counts.min() <= 1


def test_normalize_standardizes():
    x, _ = D.make_dataset(512, seed=2)
    z = D.normalize(x)
    assert abs(float(z.mean())) < 0.5
    assert 0.3 < float(z.std()) < 3.0


def test_classes_distinguishable_by_nearest_centroid():
    """A trivial classifier on raw pixels must beat chance by a wide margin —
    guarantees the accuracy signal the RL search consumes is real."""
    xtr, ytr = D.make_dataset(600, seed=10)
    xte, yte = D.make_dataset(300, seed=11)
    cents = np.stack([xtr[ytr == c].mean(axis=0).ravel() for c in range(10)])
    preds = np.argmin(((xte.reshape(len(xte), -1)[:, None, :] - cents[None]) ** 2).sum(-1), axis=1)
    acc = (preds == yte).mean()
    assert acc > 0.5, acc
