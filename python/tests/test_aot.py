"""AOT export plumbing: GTEN roundtrip, HLO text generation, input arity."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, gten, model as M


def test_gten_roundtrip():
    tensors = {
        "a": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
        "b": np.array([1, -2, 3], dtype=np.int32),
        "scalar": np.float32(3.5).reshape(()),
        "empty_name_ok": np.zeros((0,), np.float32),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.gten")
        gten.write(path, tensors)
        back = gten.read(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_gten_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.gten")
        with open(path, "wb") as f:
            f.write(b"NOPE!!")
        with pytest.raises(ValueError):
            gten.read(path)


def test_hlo_text_structure():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text
    assert "ROOT" in text


def test_export_qgemm_artifact():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "qgemm.hlo.txt")
        aot.export_qgemm(path, m=32, k=18, n=8)
        text = open(path).read()
        assert "HloModule" in text
        # 5 parameters: a, b, a_bits, w_bits, mask
        assert "parameter(4)" in text and "parameter(5)" not in text


def test_export_fwd_micro_arity():
    spec = M.VARIANTS["micro"]
    n_inputs = 1 + len(M.param_manifest(spec)) + len(M.policy_manifest(spec))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "fwd.hlo.txt")
        aot.export_fwd(spec, path, batch=2)
        text = open(path).read()
        assert f"parameter({n_inputs - 1})" in text
        assert f"parameter({n_inputs})" not in text
        assert "f32[2,32,32,3]" in text  # batch respected


def test_export_train_step_micro_arity():
    spec = M.VARIANTS["micro"]
    n_p = len(M.param_manifest(spec))
    n_t = len(M.trainable_indices(spec))
    n_q = len(M.policy_manifest(spec))
    n_inputs = 3 + n_p + n_t + n_q  # x, y, lr + params + moms + policy
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ts.hlo.txt")
        aot.export_train_step(spec, path, batch=4)
        text = open(path).read()
        assert f"parameter({n_inputs - 1})" in text
        assert f"parameter({n_inputs})" not in text
