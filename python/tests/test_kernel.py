"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Covers dense shape grids, all quantization modes (FP32 bypass / INT8 / MIX),
masks, padding edge cases (M not a multiple of the tile), plus a Hypothesis
sweep over random shapes/bit widths as demanded for kernel validation.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qgemm import qgemm
from compile.kernels.ref import qgemm_ref, fq_tensor, fq_columns

RNG = np.random.default_rng(42)


def _case(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    mask = (rng.random(n) > 0.25).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(mask)


def _check(m, k, n, a_bits, w_bits, seed=0, tile_m=128):
    a, b, mask = _case(m, k, n, seed)
    ab = jnp.float32(a_bits)
    wb = jnp.float32(w_bits)
    out = qgemm(a, b, ab, wb, mask, tile_m=tile_m)
    ref = qgemm_ref(a, b, ab, wb, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 64, 32), (130, 72, 16),
                                   (1, 9, 8), (257, 288, 8), (64, 2304, 16)])
@pytest.mark.parametrize("a_bits,w_bits", [(0, 0), (8, 8), (4, 4), (2, 6), (1, 1), (0, 5), (3, 0)])
def test_qgemm_matches_ref(m, k, n, a_bits, w_bits):
    _check(m, k, n, a_bits, w_bits)


def test_fp32_bypass_is_exact_gemm():
    a, b, mask = _case(64, 32, 16)
    out = qgemm(a, b, jnp.float32(0), jnp.float32(0), mask)
    ref = (np.asarray(a) @ np.asarray(b)) * np.asarray(mask)[None, :]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


def test_mask_zeroes_columns():
    a, b, _ = _case(32, 16, 8)
    mask = jnp.asarray(np.array([1, 0, 1, 0, 0, 1, 1, 0], np.float32))
    out = np.asarray(qgemm(a, b, jnp.float32(4), jnp.float32(4), mask))
    assert np.all(out[:, np.asarray(mask) == 0] == 0)
    assert np.any(out[:, np.asarray(mask) == 1] != 0)


def test_quant_error_shrinks_with_bits():
    """More bits => closer to the FP32 GEMM (monotone in expectation)."""
    a, b, mask = _case(96, 64, 16, seed=3)
    exact = np.asarray(a) @ np.asarray(b)
    errs = []
    for bits in [2, 4, 6, 8]:
        out = np.asarray(qgemm(a, b, jnp.float32(bits), jnp.float32(bits),
                               jnp.ones(16, jnp.float32)))
        errs.append(np.abs(out - exact).mean())
    assert errs[0] > errs[1] > errs[2] > errs[3]


def test_tile_boundary_independence():
    """Result must not depend on the M tile size (padding correctness)."""
    a, b, mask = _case(100, 32, 8, seed=5)
    outs = [np.asarray(qgemm(a, b, jnp.float32(5), jnp.float32(3), mask, tile_m=t))
            for t in (16, 32, 128)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_fq_tensor_range():
    x = jnp.asarray(RNG.normal(size=(50, 20)).astype(np.float32))
    for bits in [1, 2, 4, 8]:
        fq = np.asarray(fq_tensor(x, jnp.float32(bits)))
        # distinct reconstruction levels bounded by the bit budget
        assert len(np.unique(fq.round(5))) <= 2 ** (bits + 1)


def test_fq_columns_independent():
    """Scaling one column must not change the quantization of the others."""
    x = RNG.normal(size=(64, 4)).astype(np.float32)
    base = np.asarray(fq_columns(jnp.asarray(x), jnp.float32(4)))
    x2 = x.copy()
    x2[:, 0] *= 100.0
    mod = np.asarray(fq_columns(jnp.asarray(x2), jnp.float32(4)))
    np.testing.assert_allclose(base[:, 1:], mod[:, 1:], rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 96),
    n=st.integers(1, 24),
    a_bits=st.sampled_from([0.0, 1.0, 2.0, 3.0, 5.0, 8.0]),
    w_bits=st.sampled_from([0.0, 1.0, 4.0, 6.0, 8.0]),
    seed=st.integers(0, 2 ** 16),
)
def test_qgemm_hypothesis(m, k, n, a_bits, w_bits, seed):
    _check(m, k, n, a_bits, w_bits, seed=seed)
