"""AOT export: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model variant this produces, under artifacts/:

    meta_<v>.json            structural manifest for the Rust model IR
    weights_<v>.gten         trained parameters (manifest order)
    data_<v>.gten            val/test splits (normalized) + retrain pool
    model_fwd_<v>.hlo.txt    logits = f(x, *params, *policy)   [eval batch]
    train_step_<v>.hlo.txt   one frozen-BN SGD-momentum fine-tune step
    model_fwd_pallas_<v>.hlo.txt  (micro only) conv via the L1 Pallas kernel
    qgemm_pallas.hlo.txt     standalone fused-qgemm kernel artifact

Run via `make artifacts`; skipped when outputs are newer than inputs.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import gten
from . import model as model_mod
from . import train as train_mod
from .kernels import qgemm as qgemm_kernel

EVAL_BATCH = 128
TRAIN_BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec_structs(shapes: list[list[int]]):
    return [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for s in shapes]


def export_fwd(spec, out_path: str, *, use_pallas: bool = False,
               batch: int = EVAL_BATCH) -> None:
    pm = model_mod.param_manifest(spec)
    qm = model_mod.policy_manifest(spec)
    n_p, n_q = len(pm), len(qm)

    def fn(x, *rest):
        params = list(rest[:n_p])
        policy = list(rest[n_p:])
        return (model_mod.forward(spec, params, policy, x, use_pallas=use_pallas),)

    args = ([jax.ShapeDtypeStruct((batch, spec.img, spec.img, 3), jnp.float32)]
            + _spec_structs([m["shape"] for m in pm])
            + _spec_structs([m["shape"] for m in qm]))
    lowered = jax.jit(fn).lower(*args)
    with open(out_path, "w") as f:
        f.write(to_hlo_text(lowered))
    assert n_q == len(qm)


def export_train_step(spec, out_path: str, *, batch: int = TRAIN_BATCH) -> None:
    pm = model_mod.param_manifest(spec)
    qm = model_mod.policy_manifest(spec)
    tidx = model_mod.trainable_indices(spec)
    n_p, n_t, n_q = len(pm), len(tidx), len(qm)

    def fn(x, y, lr, *rest):
        params = list(rest[:n_p])
        moms = list(rest[n_p:n_p + n_t])
        policy = list(rest[n_p + n_t:])
        loss, new_p, new_m = model_mod.train_step(spec, params, moms, policy, x, y, lr)
        return tuple([loss] + new_p + new_m)

    args = ([jax.ShapeDtypeStruct((batch, spec.img, spec.img, 3), jnp.float32),
             jax.ShapeDtypeStruct((batch,), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.float32)]
            + _spec_structs([m["shape"] for m in pm])
            + _spec_structs([pm[i]["shape"] for i in tidx])
            + _spec_structs([m["shape"] for m in qm]))
    lowered = jax.jit(fn).lower(*args)
    with open(out_path, "w") as f:
        f.write(to_hlo_text(lowered))
    assert n_q == len(qm)


def export_qgemm(out_path: str, m: int = 256, k: int = 288, n: int = 32) -> None:
    """Standalone L1 kernel artifact (used by runtime tests + kernel bench)."""
    def fn(a, b, a_bits, w_bits, mask):
        return (qgemm_kernel.qgemm(a, b, a_bits, w_bits, mask),)

    args = [jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32)]
    lowered = jax.jit(fn).lower(*args)
    with open(out_path, "w") as f:
        f.write(to_hlo_text(lowered))


def export_dataset(spec, out_path: str, *, seed: int) -> None:
    """Validation/test/retrain splits. Validation feeds the search reward and
    the sensitivity analysis (paper: split from the train set); test is only
    used for the final reported accuracy; retrain pool feeds fine-tuning."""
    val_x, val_y = data_mod.make_dataset(2048, seed=seed + 1000)
    test_x, test_y = data_mod.make_dataset(2048, seed=seed + 2000)
    retrain_x, retrain_y = data_mod.make_dataset(4096, seed=seed + 3000)
    gten.write(out_path, {
        "val_x": data_mod.normalize(val_x), "val_y": val_y,
        "test_x": data_mod.normalize(test_x), "test_y": test_y,
        "retrain_x": data_mod.normalize(retrain_x), "retrain_y": retrain_y,
    })


def export_variant(variant: str, out_dir: str, *, train_steps: int, seed: int) -> None:
    spec = model_mod.VARIANTS[variant]
    t0 = time.time()
    print(f"=== exporting {variant} ===", flush=True)

    meta = model_mod.manifest(spec)
    meta["eval_batch"] = EVAL_BATCH
    meta["train_batch"] = TRAIN_BATCH
    with open(os.path.join(out_dir, f"meta_{variant}.json"), "w") as f:
        json.dump(meta, f, indent=1)

    export_dataset(spec, os.path.join(out_dir, f"data_{variant}.gten"), seed=seed)

    params = train_mod.train(spec, steps=train_steps, seed=seed)
    dataset = gten.read(os.path.join(out_dir, f"data_{variant}.gten"))
    test_acc = train_mod.evaluate(spec, [jnp.asarray(p) for p in params],
                                  dataset["test_x"], dataset["test_y"])
    print(f"[{variant}] uncompressed test accuracy: {test_acc:.4f}", flush=True)
    gten.write(os.path.join(out_dir, f"weights_{variant}.gten"),
               {m["name"]: p for m, p in zip(model_mod.param_manifest(spec), params)})
    with open(os.path.join(out_dir, f"meta_{variant}.json")) as f:
        meta = json.load(f)
    meta["base_test_acc"] = test_acc
    with open(os.path.join(out_dir, f"meta_{variant}.json"), "w") as f:
        json.dump(meta, f, indent=1)

    export_fwd(spec, os.path.join(out_dir, f"model_fwd_{variant}.hlo.txt"))
    export_train_step(spec, os.path.join(out_dir, f"train_step_{variant}.hlo.txt"))
    if variant == "micro":
        export_fwd(spec, os.path.join(out_dir, f"model_fwd_pallas_{variant}.hlo.txt"),
                   use_pallas=True, batch=16)
    print(f"=== {variant} done in {time.time() - t0:.1f}s ===", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="micro,resnet18s")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--micro-train-steps", type=int, default=250)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    export_qgemm(os.path.join(args.out_dir, "qgemm_pallas.hlo.txt"))
    for variant in args.variants.split(","):
        steps = args.micro_train_steps if variant == "micro" else args.train_steps
        export_variant(variant, args.out_dir, train_steps=steps, seed=args.seed)
    # stamp for make
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write(str(time.time()))


if __name__ == "__main__":
    main()
