"""Build-time pre-training of the compressible model on the synthetic dataset.

The paper starts from a *trained* ResNet18.  This module produces that
starting point: it trains the uncompressed model (batch-statistics BN, no
quantization ops in the graph for speed) with Adam + cosine schedule on the
seeded synthetic dataset, tracks BN running statistics, and returns the flat
parameter list in `model.param_manifest` order so the frozen-BN compressed
graphs can consume it directly.

Runs once inside `make artifacts`; never on the search path.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod

BN_MOMENTUM = 0.9


# --------------------------------------------------------------------------
# Plain (uncompressed, batch-stats BN) training forward
# --------------------------------------------------------------------------

def _forward_train(spec, params, x):
    """Uncompressed forward with batch-stats BN; returns (logits, stats).

    stats maps bn param-index -> (batch_mean, batch_var) for the running
    update.  Mirrors model.forward's topology exactly.
    """
    convs, _fc = model_mod.conv_specs(spec)
    pidx, _ = model_mod._index_maps(spec)
    stats: dict[int, tuple] = {}

    def conv_block(h, c):
        w = params[pidx[f"{c.name}.w"]]
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=(c.stride, c.stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        mean = jnp.mean(h, axis=(0, 1, 2))
        var = jnp.var(h, axis=(0, 1, 2))
        stats[pidx[f"{c.name}.bn.mean"]] = (mean, var)
        gamma = params[pidx[f"{c.name}.bn.gamma"]]
        beta = params[pidx[f"{c.name}.bn.beta"]]
        inv = gamma / jnp.sqrt(var + model_mod.BN_EPS)
        return h * inv + (beta - mean * inv)

    by_name = {c.name: c for c in convs}
    h = jax.nn.relu(conv_block(x, by_name["stem"]))
    for si in range(len(spec.blocks)):
        for bi in range(spec.blocks[si]):
            name = f"s{si}b{bi}"
            identity = h
            h = jax.nn.relu(conv_block(h, by_name[f"{name}.conv1"]))
            h = conv_block(h, by_name[f"{name}.conv2"])
            if f"{name}.down" in by_name:
                identity = conv_block(identity, by_name[f"{name}.down"])
            h = jax.nn.relu(h + identity)
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params[pidx["fc.w"]] + params[pidx["fc.b"]]
    return logits, stats


def _loss_train(spec, tparams, frozen, tidx, x, y):
    full = list(frozen)
    for j, i in enumerate(tidx):
        full[i] = tparams[j]
    logits, stats = _forward_train(spec, full, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == y).mean()
    return nll, (stats, acc)


# --------------------------------------------------------------------------
# Adam
# --------------------------------------------------------------------------

def _adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return -lr * mh / (jnp.sqrt(vh) + eps), m, v


@functools.partial(jax.jit, static_argnums=(0,))
def _train_step(spec, tparams, frozen, ms, vs, t, lr, x, y):
    tidx = tuple(model_mod.trainable_indices(spec))
    (loss, (stats, acc)), grads = jax.value_and_grad(
        _loss_train, argnums=1, has_aux=True)(spec, tparams, frozen, tidx, x, y)
    new_t, new_m, new_v = [], [], []
    for p, g, m, v in zip(tparams, grads, ms, vs):
        upd, m2, v2 = _adam_update(g, m, v, t, lr)
        new_t.append(p + upd)
        new_m.append(m2)
        new_v.append(v2)
    # BN running-stat update on the frozen list
    new_frozen = list(frozen)
    for mean_idx, (bm, bv) in stats.items():
        var_idx = mean_idx + 1  # manifest order: ..., mean, var
        new_frozen[mean_idx] = BN_MOMENTUM * frozen[mean_idx] + (1 - BN_MOMENTUM) * bm
        new_frozen[var_idx] = BN_MOMENTUM * frozen[var_idx] + (1 - BN_MOMENTUM) * bv
    return new_t, new_frozen, new_m, new_v, loss, acc


@functools.partial(jax.jit, static_argnums=(0,))
def _eval_logits(spec, params, x):
    policy = [jnp.asarray(p) for p in model_mod.identity_policy(spec)]
    return model_mod.forward(spec, params, policy, x)


def evaluate(spec, params, x, y, batch: int = 256) -> float:
    """Test accuracy of the frozen-BN (deployment) graph."""
    correct = 0
    for i in range(0, len(x), batch):
        logits = _eval_logits(spec, params, jnp.asarray(x[i:i + batch]))
        correct += int((np.argmax(np.asarray(logits), -1) == y[i:i + batch]).sum())
    return correct / len(x)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def train(spec: model_mod.ModelSpec, *, steps: int = 400, batch: int = 128,
          lr: float = 2e-3, train_n: int = 8192, seed: int = 7,
          log_every: int = 50) -> list[np.ndarray]:
    """Train from scratch; returns params in manifest order (numpy)."""
    xs, ys = data_mod.make_dataset(train_n, seed=seed)
    xs = data_mod.normalize(xs)
    params = [jnp.asarray(p) for p in model_mod.init_params(spec, seed=seed)]
    tidx = model_mod.trainable_indices(spec)
    tparams = [params[i] for i in tidx]
    frozen = list(params)
    ms = [jnp.zeros_like(p) for p in tparams]
    vs = [jnp.zeros_like(p) for p in tparams]

    rng = np.random.default_rng(seed + 1)
    t0 = time.time()
    for step in range(1, steps + 1):
        idx = rng.integers(0, train_n, size=batch)
        x = jnp.asarray(xs[idx])
        y = jnp.asarray(ys[idx].astype(np.int32))
        # cosine schedule with short warmup
        warm = min(1.0, step / 30.0)
        lr_t = lr * warm * 0.5 * (1 + np.cos(np.pi * step / steps))
        tparams, frozen, ms, vs, loss, acc = _train_step(
            spec, tparams, frozen, ms, vs,
            jnp.asarray(step, jnp.float32), jnp.asarray(lr_t, jnp.float32), x, y)
        if step % log_every == 0 or step == steps:
            print(f"[train:{spec.variant}] step {step}/{steps} "
                  f"loss={float(loss):.4f} batch_acc={float(acc):.3f} "
                  f"({time.time() - t0:.1f}s)", flush=True)

    full = list(frozen)
    for j, i in enumerate(tidx):
        full[i] = tparams[j]
    return [np.asarray(p, dtype=np.float32) for p in full]
