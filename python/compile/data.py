"""Synthetic 10-class 32x32x3 image dataset ("CIFAR-S").

The paper evaluates on CIFAR-10. Training real CIFAR-10 to >90% on a CPU-only
build machine is out of budget, so we procedurally generate a seeded dataset
with comparable structure (documented in DESIGN.md):

* each class has a characteristic *spatial* structure (oriented gratings,
  rings, blobs, checkers at class-specific frequency/orientation),
* each class has a characteristic but overlapping color distribution,
* samples are perturbed with random phase/shift/scale, per-sample color
  jitter, and additive Gaussian pixel noise.

The generator is pure numpy, fully determined by (seed, index), so Python
(train/eval export) and any later re-generation agree bit-for-bit.
"""
from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMG = 32
CHANNELS = 3

# Per-class base hue (RGB weights) -- overlapping on purpose.
_CLASS_COLOR = np.array(
    [
        [0.9, 0.2, 0.2],
        [0.2, 0.9, 0.2],
        [0.2, 0.2, 0.9],
        [0.8, 0.8, 0.2],
        [0.8, 0.2, 0.8],
        [0.2, 0.8, 0.8],
        [0.9, 0.5, 0.1],
        [0.5, 0.5, 0.9],
        [0.7, 0.7, 0.7],
        [0.4, 0.9, 0.5],
    ],
    dtype=np.float32,
)

# Per-class spatial pattern parameters: (kind, frequency, orientation)
# kinds: 0 grating, 1 rings, 2 blob, 3 checker
_CLASS_PATTERN = [
    (0, 2.0, 0.0),
    (0, 4.0, np.pi / 4),
    (1, 2.5, 0.0),
    (1, 5.0, 0.0),
    (2, 1.0, 0.0),
    (2, 2.0, 0.0),
    (3, 2.0, 0.0),
    (3, 4.0, 0.0),
    (0, 6.0, np.pi / 2),
    (1, 3.5, 0.0),
]


def _pattern(kind: int, freq: float, theta: float, rng: np.random.Generator) -> np.ndarray:
    """One HxW grayscale pattern in [-1, 1] with random phase/offset."""
    y, x = np.meshgrid(
        np.linspace(-1, 1, IMG, dtype=np.float32),
        np.linspace(-1, 1, IMG, dtype=np.float32),
        indexing="ij",
    )
    phase = rng.uniform(0, 2 * np.pi)
    jt = theta + rng.normal(0, 0.15)
    cx, cy = rng.uniform(-0.35, 0.35, size=2)
    if kind == 0:  # oriented grating
        u = np.cos(jt) * (x - cx) + np.sin(jt) * (y - cy)
        return np.sin(2 * np.pi * freq * u + phase)
    if kind == 1:  # concentric rings
        r = np.sqrt((x - cx) ** 2 + (y - cy) ** 2)
        return np.sin(2 * np.pi * freq * r + phase)
    if kind == 2:  # gaussian blobs grid
        s = 0.18 / freq
        g = np.exp(-(((x - cx) ** 2 + (y - cy) ** 2) / (2 * s * s)))
        g2 = np.exp(-(((x + cx) ** 2 + (y + cy) ** 2) / (2 * s * s)))
        return 2.0 * np.clip(g + g2, 0, 1) - 1.0
    # checkerboard
    u = np.cos(jt) * (x - cx) + np.sin(jt) * (y - cy)
    v = -np.sin(jt) * (x - cx) + np.cos(jt) * (y - cy)
    return np.sign(np.sin(2 * np.pi * freq * u + phase) * np.sin(2 * np.pi * freq * v + phase))


def make_sample(label: int, rng: np.random.Generator) -> np.ndarray:
    kind, freq, theta = _CLASS_PATTERN[label]
    pat = _pattern(kind, freq, theta, rng).astype(np.float32)  # HxW in [-1,1]
    color = _CLASS_COLOR[label] + rng.normal(0, 0.12, size=3).astype(np.float32)
    img = 0.5 + 0.45 * pat[..., None] * color[None, None, :]
    img += rng.normal(0, 0.08, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (x [n,32,32,3] float32 in [0,1], y [n] int32), balanced classes."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int32) % NUM_CLASSES
    rng.shuffle(labels)
    xs = np.stack([make_sample(int(l), rng) for l in labels])
    return xs.astype(np.float32), labels.astype(np.int32)


def normalize(x: np.ndarray) -> np.ndarray:
    """Dataset-level standardization used for both train and eval."""
    mean = np.array([0.5, 0.5, 0.5], dtype=np.float32)
    std = np.array([0.27, 0.27, 0.27], dtype=np.float32)
    return (x - mean) / std
