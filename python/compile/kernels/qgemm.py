"""L1 Pallas kernel: fused fake-quantize -> GEMM -> channel-mask (`qgemm`).

This is the compute hot-spot of a compressed convolution layer: after
im2col, a conv is a GEMM  A[M, K] @ B[K, N]  where N is the output-channel
dimension.  Galen's compressed layers additionally (a) fake-quantize the
activations A with a runtime bit width, (b) fake-quantize the weights B per
output channel with another runtime bit width, and (c) zero the structurally
pruned output channels.  Fusing all three into the GEMM avoids materializing
the quantized tensors in HBM — on TPU the quantize/dequantize runs on the
VPU while tiles stream through VMEM into the MXU.

TPU mapping (documented, since CPU lowering uses interpret=True):
  * grid over M tiles of TM=128 rows; each grid step holds
    A-tile (128, K), B (K, N), accumulator (128, N) in VMEM.  For the
    experiment models K <= 2304, N <= 256 => <= 2.6 MiB per step, well under
    VMEM.  The inner `aq @ bq` maps onto the 128x128 MXU.
  * B's per-column min/max is recomputed per grid step from the resident
    tile (K is never split), so no cross-step reduction is needed.
  * A's range is *per tensor* (paper: activations use tensor-level dynamic
    range after im2col), so it is reduced once outside the kernel and passed
    in as two scalars — otherwise each M-tile would see a different range.

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-8
TILE_M = 128


def _fq_block(x, bits, x_min, x_max):
    """Eq. 3 fake quantization on a resident block (VPU-friendly ops only)."""
    b = jnp.maximum(bits, 1.0)
    n = jnp.exp2(b) - 1.0
    half = jnp.exp2(b - 1.0)
    s = n / jnp.maximum(x_max - x_min, _EPS)
    z = jnp.floor(s * x_min) + half
    q = jnp.clip(jnp.floor(s * x - z), -n, n)
    fq = (q + z) / s
    return jnp.where(bits >= 0.5, fq, x)


def _qgemm_kernel(a_ref, b_ref, a_bits_ref, w_bits_ref, a_min_ref, a_max_ref,
                  mask_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    a_bits = a_bits_ref[0, 0]
    w_bits = w_bits_ref[0, 0]

    # Activations: per-tensor range, precomputed outside (see module doc).
    aq = _fq_block(a, a_bits, a_min_ref[0, 0], a_max_ref[0, 0])

    # Weights: per-output-channel (= per-column) dynamic range, computed on
    # the resident tile. K is never split so this is the exact range.
    b_min = jnp.min(b, axis=0, keepdims=True)
    b_max = jnp.max(b, axis=0, keepdims=True)
    bq = _fq_block(b, w_bits, b_min, b_max)

    acc = jnp.dot(aq, bq, preferred_element_type=jnp.float32)
    o_ref[...] = acc * mask_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def qgemm(a: jnp.ndarray, b: jnp.ndarray, a_bits: jnp.ndarray,
          w_bits: jnp.ndarray, mask: jnp.ndarray, *, tile_m: int = TILE_M) -> jnp.ndarray:
    """Fused fake-quant GEMM with output-channel masking.

    a: [M, K] activations (im2col patches), b: [K, N] weights,
    a_bits / w_bits: scalar runtime bit widths (0 => FP32 bypass),
    mask: [N] 0/1 pruning mask.  Returns [M, N] float32.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert mask.shape == (n,), f"mask shape {mask.shape} != ({n},)"

    tm = min(tile_m, m)
    pad = (-m) % tm
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    mp = m + pad

    a_min = jnp.min(a[:m] if pad else a).reshape(1, 1)
    a_max = jnp.max(a[:m] if pad else a).reshape(1, 1)
    scalar = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _qgemm_kernel,
        grid=(mp // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(a, b, scalar(a_bits), scalar(w_bits), a_min, a_max,
      mask.astype(jnp.float32).reshape(1, n))
    return out[:m]
