"""Pure-jnp oracle for the fused quantized-GEMM kernel (`qgemm.py`).

This module is the correctness contract of the L1 Pallas kernel: the kernel
must match `qgemm_ref` to float tolerance for every shape/bit-width/mask
combination.  pytest (incl. Hypothesis sweeps) enforces it at build time.

Semantics (mirrors the hot spot of a compressed conv layer lowered to GEMM
via im2col):

    out = (FQ_tensor(A, a_bits) @ FQ_col(B, w_bits)) * mask[None, :]

* A [M, K] — im2col activation patches; fake-quantized per-tensor with the
  runtime activation bit width `a_bits` (0 => FP32 bypass).
* B [K, N] — reshaped conv weights, N = output channels; fake-quantized
  per column (i.e. per output channel, the paper's dynamic per-channel
  calibration) with runtime weight bit width `w_bits` (0 => bypass).
* mask [N] — 0/1 structured-pruning channel mask applied to the output.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-8


def _fq(x: jnp.ndarray, bits: jnp.ndarray, x_min: jnp.ndarray, x_max: jnp.ndarray) -> jnp.ndarray:
    b = jnp.maximum(bits, 1.0)
    n = jnp.exp2(b) - 1.0
    half = jnp.exp2(b - 1.0)
    s = n / jnp.maximum(x_max - x_min, _EPS)
    z = jnp.floor(s * x_min) + half
    q = jnp.clip(jnp.floor(s * x - z), -n, n)
    fq = (q + z) / s
    return jnp.where(bits >= 0.5, fq, x)


def fq_tensor(a: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor fake quantization (activations after im2col)."""
    return _fq(a, bits, jnp.min(a), jnp.max(a))


def fq_columns(b: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Per-column (output-channel) fake quantization (weights)."""
    x_min = jnp.min(b, axis=0, keepdims=True)
    x_max = jnp.max(b, axis=0, keepdims=True)
    return _fq(b, bits, x_min, x_max)


def qgemm_ref(a: jnp.ndarray, b: jnp.ndarray, a_bits: jnp.ndarray,
              w_bits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    aq = fq_tensor(a, a_bits)
    bq = fq_columns(b, w_bits)
    return (aq @ bq) * mask[None, :]
