"""Fake quantization (paper Eq. 3) with *runtime* bit widths.

The paper quantizes value r as

    Q(r) = max(-n, min(n, floor(s * r - z)))

with n = 2^b - 1, scale s = n / (x_max - x_min), offset
z = floor(s * x_min) + 2^(b-1), and dynamic per-channel range calibration
(x_min / x_max taken from the tensor itself).

Crucially for this reproduction the bit width ``b`` is a *traced scalar
input* of the AOT-compiled graph, not a Python constant: one compiled
artifact serves every quantization policy.  ``b < 0.5`` bypasses
quantization entirely (the FP32 option).  ``b = 8`` realizes INT8 and
``1 <= b <= 6`` the MIX options of the paper.

`fake_quant` is the eval-path op; `fake_quant_ste` is the training-path op
with a straight-through estimator so retraining (paper: 30 epochs after the
search) differentiates through the quantizer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-8


def _channel_min_max(x: jnp.ndarray, axis: int | None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dynamic range: reduce over all axes except `axis` (None => per-tensor)."""
    if axis is None:
        axes = tuple(range(x.ndim))
    else:
        axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    x_min = jnp.min(x, axis=axes, keepdims=True)
    x_max = jnp.max(x, axis=axes, keepdims=True)
    return x_min, x_max


def quantize(x: jnp.ndarray, bits: jnp.ndarray, axis: int) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Paper Eq. 3. Returns (q, s, z); all dtype float32 (q holds integers).

    bits: scalar float tensor (traced). Caller guarantees bits >= 1 when the
    result is used; see `fake_quant` for the bits==0 bypass.
    """
    b = jnp.maximum(bits, 1.0)
    n = jnp.exp2(b) - 1.0
    half = jnp.exp2(b - 1.0)
    x_min, x_max = _channel_min_max(x, axis)
    s = n / jnp.maximum(x_max - x_min, _EPS)
    z = jnp.floor(s * x_min) + half
    q = jnp.clip(jnp.floor(s * x - z), -n, n)
    return q, s, z


def dequantize(q: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    return (q + z) / s


def fake_quant(x: jnp.ndarray, bits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Quantize-dequantize with runtime bit width; bits < 0.5 bypasses (FP32)."""
    q, s, z = quantize(x, bits, axis)
    fq = dequantize(q, s, z)
    return jnp.where(bits >= 0.5, fq, x)


def fake_quant_ste(x: jnp.ndarray, bits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """fake_quant with a straight-through estimator for the backward pass."""
    fq = fake_quant(x, bits, axis)
    return x + jax.lax.stop_gradient(fq - x)
