"""GTEN: a minimal named-tensor container for the Python -> Rust handoff.

numpy's .npz is a zip container that would force a zip+npy parser into the
Rust side; instead we define a trivial little-endian binary format that both
sides implement from scratch (Rust reader: rust/src/util/gten.rs).

Layout (all integers little-endian):

    magic   b"GTEN1\n"
    u32     tensor count
    per tensor:
        u16     name length, then name bytes (utf-8)
        u8      dtype: 0 = f32, 1 = i32
        u8      ndim
        u32     dims[ndim]
        f32/i32 data (row-major), prod(dims) elements
"""
from __future__ import annotations

import struct

import numpy as np

MAGIC = b"GTEN1\n"
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        if f.read(len(MAGIC)) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        out: dict[str, np.ndarray] = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = _DTYPES[code]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * 4), dtype=dtype, count=n)
            out[name] = data.reshape(dims).copy()
        return out
