"""L2: policy-parameterized ResNet family (JAX, build-time only).

The paper compresses a trained ResNet18 (CIFAR-10 variant: 3x3 stem, four
stages of BasicBlocks, widths w0*{1,2,4,8}).  Because the Rust search loop
may never call back into Python, the *entire compression policy is part of
the compiled graph's runtime inputs*:

  logits = f(x, *params, *policy)

where per conv layer the policy contributes (mask[c_out], w_bits, a_bits)
and the final linear contributes (w_bits, a_bits).  See DESIGN.md
"Compression-as-runtime-inputs".

* pruning: 0/1 channel mask multiplied after BN — numerically identical to
  structurally removing the channels (they contribute zero downstream).
* quantization: Eq. 3 fake quantization with runtime bit widths
  (0 => FP32 bypass, 8 => INT8, 1..6 => MIX), dynamic per-channel ranges.
* BN is frozen (running statistics as graph inputs) in both the eval and the
  retraining graph: retraining a compressed model with frozen BN statistics
  is standard fine-tuning practice and keeps the train-step artifact
  stateless apart from params/momenta.

Three model variants (same topology, different width/depth) are exported:
`micro` for fast tests, `resnet18s` for the paper-scale experiments on a CPU
budget, `resnet18` full width.  The structural metadata Rust needs (layer
graph, pruning-dependency groups, parameter/policy manifests) is emitted by
`manifest()` and serialized to `artifacts/meta_<variant>.json` by aot.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import qgemm as qgemm_kernel

BN_EPS = 1e-5


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    cin: int
    cout: int
    kernel: int
    stride: int
    in_spatial: int
    out_spatial: int
    prunable: bool      # independently prunable (not in a residual group)
    group: int          # pruning-dependency group id (-1: none / independent)
    relu: bool          # ReLU directly after BN+mask (block conv2: fused later)


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    name: str
    cin: int
    cout: int


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    variant: str
    width: int                  # stem width w0
    blocks: tuple[int, ...]     # BasicBlocks per stage
    img: int = 32
    classes: int = 10

    @property
    def stage_widths(self) -> tuple[int, ...]:
        return tuple(self.width * (2 ** i) for i in range(len(self.blocks)))


VARIANTS: dict[str, ModelSpec] = {
    "micro": ModelSpec("micro", width=8, blocks=(1, 1, 1, 1)),
    "resnet18s": ModelSpec("resnet18s", width=32, blocks=(2, 2, 2, 2)),
    "resnet18": ModelSpec("resnet18", width=64, blocks=(2, 2, 2, 2)),
}


def conv_specs(spec: ModelSpec) -> tuple[list[ConvSpec], LinearSpec]:
    """Enumerate conv layers in forward order with dependency groups.

    Group g_i is the residual *stream* of stage i: the stem (stage 0) or the
    downsample projection (later stages) plus every block's conv2 output.
    All members must share one channel mask, hence none is independently
    prunable (the paper's "gray" layers).  Each block's conv1 is the inner
    width and independently prunable.
    """
    convs: list[ConvSpec] = []
    sp = spec.img
    widths = spec.stage_widths
    convs.append(ConvSpec("stem", 3, widths[0], 3, 1, sp, sp, False, 0, True))
    cin = widths[0]
    for si, (w, nb) in enumerate(zip(widths, spec.blocks)):
        stride = 1 if si == 0 else 2
        for bi in range(nb):
            s = stride if bi == 0 else 1
            out_sp = sp // s
            name = f"s{si}b{bi}"
            convs.append(ConvSpec(f"{name}.conv1", cin, w, 3, s, sp, out_sp,
                                  True, -1, True))
            convs.append(ConvSpec(f"{name}.conv2", w, w, 3, 1, out_sp, out_sp,
                                  False, si, False))
            if bi == 0 and (s != 1 or cin != w):
                convs.append(ConvSpec(f"{name}.down", cin, w, 1, s, sp, out_sp,
                                      False, si, False))
            cin = w
            sp = out_sp
    fc = LinearSpec("fc", widths[-1], spec.classes)
    return convs, fc


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_manifest(spec: ModelSpec) -> list[dict]:
    """Flat, ordered parameter list: the artifact input contract."""
    convs, fc = conv_specs(spec)
    out: list[dict] = []
    for c in convs:
        out.append({"name": f"{c.name}.w", "shape": [c.kernel, c.kernel, c.cin, c.cout],
                    "trainable": True})
        for p, tr in (("gamma", True), ("beta", True), ("mean", False), ("var", False)):
            out.append({"name": f"{c.name}.bn.{p}", "shape": [c.cout], "trainable": tr})
    out.append({"name": "fc.w", "shape": [fc.cin, fc.cout], "trainable": True})
    out.append({"name": "fc.b", "shape": [fc.cout], "trainable": True})
    return out


def policy_manifest(spec: ModelSpec) -> list[dict]:
    """Flat, ordered policy-input list (mask + bit widths per layer)."""
    convs, _fc = conv_specs(spec)
    out: list[dict] = []
    for c in convs:
        out.append({"name": f"{c.name}.mask", "shape": [c.cout]})
        out.append({"name": f"{c.name}.w_bits", "shape": []})
        out.append({"name": f"{c.name}.a_bits", "shape": []})
    out.append({"name": "fc.w_bits", "shape": []})
    out.append({"name": "fc.a_bits", "shape": []})
    return out


def init_params(spec: ModelSpec, seed: int = 0) -> list[np.ndarray]:
    """He-init conv weights; BN gamma=1 beta=0 mean=0 var=1; zero-init fc bias."""
    rng = np.random.default_rng(seed)
    params: list[np.ndarray] = []
    for m in param_manifest(spec):
        shape = tuple(m["shape"])
        name = m["name"]
        if name.endswith(".w") and len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            params.append(rng.normal(0, np.sqrt(2.0 / fan_in), shape).astype(np.float32))
        elif name == "fc.w":
            params.append(rng.normal(0, np.sqrt(1.0 / shape[0]), shape).astype(np.float32))
        elif name.endswith(".gamma") or name.endswith(".var"):
            params.append(np.ones(shape, np.float32))
        else:  # beta, mean, fc.b
            params.append(np.zeros(shape, np.float32))
    return params


def identity_policy(spec: ModelSpec) -> list[np.ndarray]:
    """The reference (no-compression) policy P_r: all masks 1, all bits 0."""
    out: list[np.ndarray] = []
    for m in policy_manifest(spec):
        shape = tuple(m["shape"])
        out.append(np.ones(shape, np.float32) if m["name"].endswith(".mask")
                   else np.zeros(shape, np.float32))
    return out


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _index_maps(spec: ModelSpec):
    pm = param_manifest(spec)
    qm = policy_manifest(spec)
    pidx = {m["name"]: i for i, m in enumerate(pm)}
    qidx = {m["name"]: i for i, m in enumerate(qm)}
    return pidx, qidx


def _qconv_xla(x, w, a_bits, w_bits, stride):
    """Per-channel fake-quantized conv (NHWC x HWIO), STE-differentiable."""
    xq = quant.fake_quant_ste(x, a_bits, axis=-1)
    wq = quant.fake_quant_ste(w, w_bits, axis=3)
    return jax.lax.conv_general_dilated(
        xq, wq, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _qconv_pallas(x, w, a_bits, w_bits, mask, stride):
    """conv = im2col + fused L1 qgemm kernel (quant + GEMM + mask fused)."""
    n, _h, _wd, cin = x.shape
    kh, kw, _, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(kh, kw), window_strides=(stride, stride),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    oh, ow = patches.shape[1], patches.shape[2]
    # patches feature order is (cin, kh, kw) — align W accordingly.
    a = patches.reshape(n * oh * ow, cin * kh * kw)
    b = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    out = qgemm_kernel.qgemm(a, b, a_bits, w_bits, mask)
    return out.reshape(n, oh, ow, cout)


def _bn(x, gamma, beta, mean, var):
    inv = gamma / jnp.sqrt(var + BN_EPS)
    return x * inv + (beta - mean * inv)


def forward(spec: ModelSpec, params: list, policy: list, x: jnp.ndarray,
            *, use_pallas: bool = False) -> jnp.ndarray:
    """Compressed forward pass. params/policy follow the manifests exactly."""
    convs, _fc = conv_specs(spec)
    pidx, qidx = _index_maps(spec)

    def conv_block(h, c: ConvSpec):
        w = params[pidx[f"{c.name}.w"]]
        mask = policy[qidx[f"{c.name}.mask"]]
        wb = policy[qidx[f"{c.name}.w_bits"]]
        ab = policy[qidx[f"{c.name}.a_bits"]]
        if use_pallas:
            h = _qconv_pallas(h, w, ab, wb, mask, c.stride)
        else:
            h = _qconv_xla(h, w, ab, wb, c.stride)
        h = _bn(h, params[pidx[f"{c.name}.bn.gamma"]], params[pidx[f"{c.name}.bn.beta"]],
                params[pidx[f"{c.name}.bn.mean"]], params[pidx[f"{c.name}.bn.var"]])
        # Mask after BN: the BN shift would otherwise un-zero pruned channels.
        return h * mask

    by_name = {c.name: c for c in convs}
    h = conv_block(x, by_name["stem"])
    h = jax.nn.relu(h)

    for si in range(len(spec.blocks)):
        for bi in range(spec.blocks[si]):
            name = f"s{si}b{bi}"
            identity = h
            h = jax.nn.relu(conv_block(h, by_name[f"{name}.conv1"]))
            h = conv_block(h, by_name[f"{name}.conv2"])
            if f"{name}.down" in by_name:
                identity = conv_block(identity, by_name[f"{name}.down"])
            h = jax.nn.relu(h + identity)

    h = jnp.mean(h, axis=(1, 2))  # global average pool -> [N, C]
    wfc = params[pidx["fc.w"]]
    bfc = params[pidx["fc.b"]]
    hq = quant.fake_quant_ste(h, policy[qidx["fc.a_bits"]], axis=-1)
    wq = quant.fake_quant_ste(wfc, policy[qidx["fc.w_bits"]], axis=1)
    return hq @ wq + bfc


def forward_probs(spec: ModelSpec, params, policy, x, *, use_pallas=False):
    return jax.nn.softmax(forward(spec, params, policy, x, use_pallas=use_pallas), axis=-1)


# --------------------------------------------------------------------------
# Loss / training step (frozen-BN fine-tuning, SGD with momentum)
# --------------------------------------------------------------------------

def loss_fn(spec: ModelSpec, params: list, policy: list, x, y) -> jnp.ndarray:
    logits = forward(spec, params, policy, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def trainable_indices(spec: ModelSpec) -> list[int]:
    return [i for i, m in enumerate(param_manifest(spec)) if m["trainable"]]


def train_step(spec: ModelSpec, params: list, moms: list, policy: list,
               x, y, lr, momentum: float = 0.9, weight_decay: float = 5e-4):
    """One SGD-momentum step on the *trainable* params (conv W, BN affine, fc).

    Returns (loss, new_trainable_params, new_moms); both lists follow
    `trainable_indices` order.  BN running statistics are frozen inputs.
    The quantizers use straight-through estimators, so this step retrains
    *through* the compression policy, as the paper's 30-epoch fine-tune does.
    """
    tidx = trainable_indices(spec)

    def f(tparams):
        full = list(params)
        for j, i in enumerate(tidx):
            full[i] = tparams[j]
        return loss_fn(spec, full, policy, x, y)

    tparams = [params[i] for i in tidx]
    loss, grads = jax.value_and_grad(f)(tparams)
    pm = param_manifest(spec)
    new_p, new_m = [], []
    for j, i in enumerate(tidx):
        g = grads[j]
        if pm[i]["name"].endswith(".w"):  # decay conv/fc weights only
            g = g + weight_decay * tparams[j]
        m = momentum * moms[j] + g
        new_m.append(m)
        new_p.append(tparams[j] - lr * m)
    return loss, new_p, new_m


# --------------------------------------------------------------------------
# Structural manifest for the Rust model IR
# --------------------------------------------------------------------------

def manifest(spec: ModelSpec) -> dict:
    convs, fc = conv_specs(spec)
    layers = []
    for c in convs:
        layers.append({
            "name": c.name, "kind": "conv", "cin": c.cin, "cout": c.cout,
            "kernel": c.kernel, "stride": c.stride,
            "in_spatial": c.in_spatial, "out_spatial": c.out_spatial,
            "prunable": c.prunable, "group": c.group, "depthwise": False,
        })
    layers.append({
        "name": fc.name, "kind": "linear", "cin": fc.cin, "cout": fc.cout,
        "kernel": 1, "stride": 1, "in_spatial": 1, "out_spatial": 1,
        "prunable": False, "group": -1, "depthwise": False,
    })
    return {
        "variant": spec.variant,
        "img": spec.img,
        "classes": spec.classes,
        "width": spec.width,
        "blocks": list(spec.blocks),
        "layers": layers,
        "params": param_manifest(spec),
        "policy": policy_manifest(spec),
        "trainable": trainable_indices(spec),
    }
