//! PJRT client wrapper + executable handle.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// A host-side tensor destined for (or read from) the device.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// Flat f32 payload.
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Wrap a buffer (shape product must match the data length).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>().max(1),
            data.len().max(1),
            "shape {shape:?} vs {} elems",
            data.len()
        );
        Self { shape, data }
    }

    /// A rank-0 tensor holding `v`.
    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Element count (product of dims).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The PJRT CPU client.  Cloneable handle (the underlying client is
/// reference-counted by the xla crate).
pub struct PjrtRuntime {
    /// The underlying PJRT client handle.
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Bring up the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self { client })
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        log::info!("compiled artifact {}", path.display());
        Ok(Executable {
            exe,
            name: path.display().to_string(),
        })
    }

    /// Upload host tensors once; reuse across many `Executable::run_b` calls.
    pub fn upload(&self, tensors: &[HostTensor]) -> Result<DeviceTensors> {
        let mut bufs = Vec::with_capacity(tensors.len());
        for t in tensors {
            // scalars: PJRT wants rank-0; represent as dims=[]
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .map_err(|e| anyhow::anyhow!("uploading tensor: {e:?}"))?;
            bufs.push(buf);
        }
        Ok(DeviceTensors { bufs })
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, shape, None)
            .map_err(|e| anyhow::anyhow!("uploading i32 tensor: {e:?}"))
    }

    /// Upload one f32 tensor to the device.
    pub fn upload_one(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading tensor: {e:?}"))
    }
}

/// Device-resident tensors (uploaded once, used by many executions).
pub struct DeviceTensors {
    /// The device buffers, in upload order.
    pub bufs: Vec<xla::PjRtBuffer>,
}

impl DeviceTensors {
    /// Number of buffers.
    pub fn len(&self) -> usize {
        self.bufs.len()
    }
    /// Whether no buffers are held.
    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// A compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (diagnostics).
    pub name: String,
}

impl Executable {
    /// Execute with device-resident buffers; returns all tuple outputs as
    /// host tensors.  The AOT graphs are lowered with `return_tuple=True`,
    /// so the single PJRT output is a tuple literal that we decompose.
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e:?}", self.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: readback failed: {e:?}", self.name))?;
        literal_to_tensors(lit)
    }

    /// Convenience: execute from host tensors (uploads everything).
    pub fn run(&self, runtime: &PjrtRuntime, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let dev = runtime.upload(args)?;
        let refs: Vec<&xla::PjRtBuffer> = dev.bufs.iter().collect();
        self.run_b(&refs)
    }
}

/// Decompose a (possibly tuple) literal into f32 host tensors.
pub fn literal_to_tensors(lit: xla::Literal) -> Result<Vec<HostTensor>> {
    let shape = lit.shape().map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let elems = match shape {
        xla::Shape::Tuple(_) => lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple decompose: {e:?}"))?,
        _ => vec![lit],
    };
    let mut out = Vec::with_capacity(elems.len());
    for e in elems {
        let ashape = e
            .array_shape()
            .map_err(|err| anyhow::anyhow!("array shape: {err:?}"))?;
        let dims: Vec<usize> = ashape.dims().iter().map(|&d| d as usize).collect();
        let ty = e.ty().map_err(|err| anyhow::anyhow!("ty: {err:?}"))?;
        let data: Vec<f32> = match ty {
            xla::ElementType::F32 => e
                .to_vec::<f32>()
                .map_err(|err| anyhow::anyhow!("to_vec f32: {err:?}"))?,
            xla::ElementType::S32 => e
                .to_vec::<i32>()
                .map_err(|err| anyhow::anyhow!("to_vec i32: {err:?}"))?
                .into_iter()
                .map(|v| v as f32)
                .collect(),
            other => bail!("unsupported output element type {other:?}"),
        };
        out.push(HostTensor::new(dims, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        let s = HostTensor::scalar(4.0);
        assert_eq!(s.numel(), 1);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_mismatch() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }
}
