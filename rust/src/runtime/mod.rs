//! PJRT runtime: load AOT HLO-text artifacts and execute them on the hot
//! path.  Adapted from /opt/xla-example/load_hlo (HLO *text* is the
//! interchange format — see python/compile/aot.py).
//!
//! Performance-relevant design points:
//! * model parameters (11 MB for resnet18s) are uploaded to device buffers
//!   **once** (`DeviceTensors`) and reused by every `execute_b` call — only
//!   the per-episode policy inputs (a few KiB of masks/bit scalars) and the
//!   evaluation batch are re-uploaded;
//! * executables are compiled once per artifact and cached in the
//!   `ArtifactRegistry`.

mod executor;
mod registry;

pub use executor::{DeviceTensors, Executable, HostTensor, PjrtRuntime};
pub use registry::ArtifactRegistry;
