//! Artifact registry: one-stop loader for everything `make artifacts`
//! produced for a model variant (meta manifest, weights, dataset splits,
//! compiled executables).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::executor::{Executable, HostTensor, PjrtRuntime};
use crate::model::{load_meta, ModelIr, ModelMeta};
use crate::util::gten;

/// Dataset splits exported by aot.py (normalized images + int labels).
pub struct Dataset {
    /// Validation images.
    pub val_x: HostTensor,
    /// Validation labels.
    pub val_y: Vec<i32>,
    /// Test images.
    pub test_x: HostTensor,
    /// Test labels.
    pub test_y: Vec<i32>,
    /// Retraining images.
    pub retrain_x: HostTensor,
    /// Retraining labels.
    pub retrain_y: Vec<i32>,
}

/// All artifacts of one model variant.
pub struct ArtifactRegistry {
    /// Artifact directory the registry loaded from.
    pub dir: PathBuf,
    /// Model variant name.
    pub variant: String,
    /// The parsed manifest.
    pub meta: ModelMeta,
    /// The structural IR built from the manifest.
    pub ir: ModelIr,
    /// Parameter tensors in manifest order.
    pub params: Vec<HostTensor>,
    /// name -> (shape, data) view of the parameters (ℓ1 ranking etc.).
    pub params_by_name: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    /// Compiled forward graph.
    pub fwd: Executable,
    /// Compiled train-step graph (absent for eval-only exports).
    pub train_step: Option<Executable>,
    /// The exported dataset splits.
    pub dataset: Dataset,
}

impl ArtifactRegistry {
    /// Load and compile everything for `variant` from `dir`.
    pub fn load(runtime: &PjrtRuntime, dir: &Path, variant: &str) -> Result<Self> {
        Self::load_with(runtime, dir, variant, false)
    }

    /// `pallas = true` loads the Pallas-kernel forward artifact instead of
    /// the XLA-conv one (exported for the micro variant).
    pub fn load_with(
        runtime: &PjrtRuntime,
        dir: &Path,
        variant: &str,
        pallas: bool,
    ) -> Result<Self> {
        let meta = load_meta(&dir.join(format!("meta_{variant}.json")))
            .with_context(|| format!("loading meta for {variant} (run `make artifacts`?)"))?;
        let ir = ModelIr::from_meta(&meta)?;

        let weights = gten::read(&dir.join(format!("weights_{variant}.gten")))?;
        let mut params = Vec::with_capacity(meta.params.len());
        let mut params_by_name = BTreeMap::new();
        for entry in &meta.params {
            let t = weights
                .get(&entry.name)
                .with_context(|| format!("weights file missing {}", entry.name))?;
            let data = t.as_f32()?.to_vec();
            anyhow::ensure!(
                t.shape == entry.shape,
                "{}: weight shape {:?} != manifest {:?}",
                entry.name,
                t.shape,
                entry.shape
            );
            params_by_name.insert(entry.name.clone(), (t.shape.clone(), data.clone()));
            params.push(HostTensor::new(t.shape.clone(), data));
        }

        let data = gten::read(&dir.join(format!("data_{variant}.gten")))?;
        let tensor = |name: &str| -> Result<HostTensor> {
            let t = data
                .get(name)
                .with_context(|| format!("dataset missing {name}"))?;
            Ok(HostTensor::new(t.shape.clone(), t.as_f32()?.to_vec()))
        };
        let labels = |name: &str| -> Result<Vec<i32>> {
            Ok(data
                .get(name)
                .with_context(|| format!("dataset missing {name}"))?
                .as_i32()?
                .to_vec())
        };
        let dataset = Dataset {
            val_x: tensor("val_x")?,
            val_y: labels("val_y")?,
            test_x: tensor("test_x")?,
            test_y: labels("test_y")?,
            retrain_x: tensor("retrain_x")?,
            retrain_y: labels("retrain_y")?,
        };

        let fwd_name = if pallas {
            format!("model_fwd_pallas_{variant}.hlo.txt")
        } else {
            format!("model_fwd_{variant}.hlo.txt")
        };
        let fwd = runtime.load_hlo_text(&dir.join(fwd_name))?;
        let ts_path = dir.join(format!("train_step_{variant}.hlo.txt"));
        let train_step = if ts_path.exists() {
            Some(runtime.load_hlo_text(&ts_path)?)
        } else {
            None
        };

        Ok(Self {
            dir: dir.to_path_buf(),
            variant: variant.to_string(),
            meta,
            ir,
            params,
            params_by_name,
            fwd,
            train_step,
            dataset,
        })
    }
}
