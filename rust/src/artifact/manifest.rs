//! The artifact manifest: everything a consumer must know *before*
//! touching weights.
//!
//! The manifest is schema-versioned JSON (hand-rolled `util::json`, so key
//! order is canonical via `BTreeMap`) carrying the model variant, the full
//! discretized policy with layer names, the target identity (name +
//! fingerprint), the latency claim with its backend label, packaging
//! provenance, and a content digest (SHA-256 + byte length) of every
//! payload section.  Those digests form the middle of the artifact's hash
//! tree: the whole-file checksum covers the manifest bytes, the manifest
//! covers each section, and each section encoding covers its own name,
//! dtype, shape and data.

use std::collections::BTreeMap;

use crate::compress::{DiscretePolicy, LayerCmp};
use crate::util::json::Json;
use crate::util::Fnv1a;

use super::ArtifactError;

/// Manifest schema version this build writes and reads.
pub const ARTIFACT_SCHEMA_VERSION: usize = 1;

/// Content digest of one encoded payload section.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SectionDigest {
    /// Lowercase-hex SHA-256 of the section's canonical encoding.
    pub sha256: String,
    /// Length of that encoding in bytes.
    pub bytes: u64,
}

/// The latency the producer claims for this artifact, with enough context
/// to re-measure it: `galen run-artifact` replays the same policy through
/// a `LatencyProvider` and reports drift against `latency_s`.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyClaim {
    /// Claimed policy latency in seconds (the search's best episode).
    pub latency_s: f64,
    /// Uncompressed-reference latency in seconds (for relative numbers).
    pub base_latency_s: f64,
    /// Which latency backend produced the claim (`sim`/`measured`/`hybrid`).
    pub backend: String,
}

/// Where the packaged bytes came from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Provenance {
    /// Weight origin: `gten:<path>` for real AOT-exported weights,
    /// `synthetic:<seed hex>` for the deterministic in-process fallback.
    pub weights: String,
    /// Profile-cache root the latency backend ran against (`none` for the
    /// in-memory simulator path).
    pub profile_cache: String,
    /// Schema version of that profile cache format
    /// (`hw::PROFILE_SCHEMA_VERSION` at pack time).
    pub profile_schema_version: usize,
    /// Producing tool and version (`galen <crate version>`).
    pub tool: String,
}

/// The parsed, schema-checked artifact manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactManifest {
    /// Format version (see [`ARTIFACT_SCHEMA_VERSION`]).
    pub schema_version: usize,
    /// Model variant the policy compresses (`micro`/`resnet18s`/...).
    pub variant: String,
    /// IR layer names, in order — pairs with `policy.layers` and lets a
    /// consumer validate against its own IR before trusting shapes.
    pub layer_names: Vec<String>,
    /// The discretized compression policy (kept channels + quant modes).
    pub policy: DiscretePolicy,
    /// Stable 64-bit hex hash of the canonical policy JSON; also the
    /// `<policyhash>` component of the artifact file name.
    pub policy_hash: String,
    /// Hardware target name the claim was produced on.
    pub target: String,
    /// `hw` target fingerprint (16-hex): kernel-selection identity, so a
    /// device can refuse artifacts packaged for different support flags.
    pub target_fingerprint: String,
    /// Claimed latency with backend label.
    pub claim: LatencyClaim,
    /// Packaging provenance (weights origin, profile cache, tool).
    pub provenance: Provenance,
    /// Per-section content digests, keyed by section name.
    pub sections: BTreeMap<String, SectionDigest>,
}

/// Stable 16-hex policy hash over the canonical policy serialization.
/// A *fingerprint* (file naming, dedup), not an integrity check — the
/// SHA-256 tree does integrity; verification still recomputes this to
/// catch a policy edited without updating the name-bearing hash.
pub fn policy_hash(policy: &DiscretePolicy) -> String {
    let mut h = Fnv1a::new();
    h.mix_bytes(policy.to_json().dump().as_bytes());
    format!("{:016x}", h.finish())
}

impl ArtifactManifest {
    /// Canonical JSON form (BTreeMap key order → deterministic bytes).
    pub fn to_json(&self) -> Json {
        let policy: Vec<Json> = self
            .layer_names
            .iter()
            .zip(&self.policy.layers)
            .map(|(name, l)| {
                let mut j = l.to_json();
                if let Json::Obj(o) = &mut j {
                    o.insert("layer".into(), Json::str(name.clone()));
                }
                j
            })
            .collect();
        let sections = Json::Obj(
            self.sections
                .iter()
                .map(|(name, d)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("sha256", Json::str(d.sha256.clone())),
                            ("bytes", Json::num(d.bytes as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema_version", Json::num(self.schema_version as f64)),
            ("variant", Json::str(self.variant.clone())),
            ("policy", Json::Arr(policy)),
            ("policy_hash", Json::str(self.policy_hash.clone())),
            ("target", Json::str(self.target.clone())),
            ("target_fingerprint", Json::str(self.target_fingerprint.clone())),
            (
                "claim",
                Json::obj(vec![
                    ("latency_s", Json::num(self.claim.latency_s)),
                    ("base_latency_s", Json::num(self.claim.base_latency_s)),
                    ("backend", Json::str(self.claim.backend.clone())),
                ]),
            ),
            (
                "provenance",
                Json::obj(vec![
                    ("weights", Json::str(self.provenance.weights.clone())),
                    ("profile_cache", Json::str(self.provenance.profile_cache.clone())),
                    (
                        "profile_schema_version",
                        Json::num(self.provenance.profile_schema_version as f64),
                    ),
                    ("tool", Json::str(self.provenance.tool.clone())),
                ]),
            ),
            ("sections", sections),
        ])
    }

    /// Parse and structurally validate a manifest document.  The caller
    /// (`artifact::verify`) checks `schema_version` *before* this full
    /// parse so an artifact from a future format fails with the precise
    /// [`ArtifactError::SchemaVersion`] rather than a field-level error.
    pub fn from_json(j: &Json) -> Result<Self, ArtifactError> {
        (|| -> anyhow::Result<Self> {
            let schema_version = j.req_usize("schema_version")?;
            let variant = j.req_str("variant")?.to_string();
            let mut layer_names = Vec::new();
            let mut layers = Vec::new();
            for e in j.req_arr("policy")? {
                layer_names.push(e.req_str("layer")?.to_string());
                layers.push(LayerCmp::from_json(e)?);
            }
            anyhow::ensure!(!layers.is_empty(), "policy has no layers");
            let claim = j.req("claim")?;
            let prov = j.req("provenance")?;
            let mut sections = BTreeMap::new();
            let secs = j.req("sections")?;
            let obj = secs
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("'sections' is not an object"))?;
            for (name, d) in obj {
                sections.insert(
                    name.clone(),
                    SectionDigest {
                        sha256: d.req_str("sha256")?.to_string(),
                        bytes: d.req_f64("bytes")? as u64,
                    },
                );
            }
            Ok(Self {
                schema_version,
                variant,
                layer_names,
                policy: DiscretePolicy { layers },
                policy_hash: j.req_str("policy_hash")?.to_string(),
                target: j.req_str("target")?.to_string(),
                target_fingerprint: j.req_str("target_fingerprint")?.to_string(),
                claim: LatencyClaim {
                    latency_s: claim.req_f64("latency_s")?,
                    base_latency_s: claim.req_f64("base_latency_s")?,
                    backend: claim.req_str("backend")?.to_string(),
                },
                provenance: Provenance {
                    weights: prov.req_str("weights")?.to_string(),
                    profile_cache: prov.req_str("profile_cache")?.to_string(),
                    profile_schema_version: prov.req_usize("profile_schema_version")?,
                    tool: prov.req_str("tool")?.to_string(),
                },
                sections,
            })
        })()
        .map_err(|e| ArtifactError::Manifest(format!("{e:#}")))
    }

    /// Human-readable provenance / claims table (`galen report --artifact`).
    pub fn table(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "artifact manifest (schema v{})", self.schema_version);
        let _ = writeln!(s, "  variant             {}", self.variant);
        let _ = writeln!(s, "  layers              {}", self.policy.layers.len());
        let _ = writeln!(s, "  policy hash         {}", self.policy_hash);
        let _ = writeln!(s, "  target              {}", self.target);
        let _ = writeln!(s, "  target fingerprint  {}", self.target_fingerprint);
        let _ = writeln!(
            s,
            "  claimed latency     {:.3} ms ({} backend; {:.1}% of the {:.3} ms reference)",
            self.claim.latency_s * 1e3,
            self.claim.backend,
            100.0 * self.claim.latency_s / self.claim.base_latency_s,
            self.claim.base_latency_s * 1e3,
        );
        let _ = writeln!(s, "  weights             {}", self.provenance.weights);
        let _ = writeln!(
            s,
            "  profile cache       {} (schema v{})",
            self.provenance.profile_cache, self.provenance.profile_schema_version
        );
        let _ = writeln!(s, "  packaged by         {}", self.provenance.tool);
        let total: u64 = self.sections.values().map(|d| d.bytes).sum();
        let _ = writeln!(s, "  payload             {} sections, {total} bytes", self.sections.len());
        let mut quant = BTreeMap::new();
        for l in &self.policy.layers {
            *quant.entry(l.quant.label()).or_insert(0usize) += 1;
        }
        let modes: Vec<String> = quant.iter().map(|(m, n)| format!("{n} x {m}")).collect();
        let _ = writeln!(s, "  quant modes         {}", modes.join(", "));
        let _ = writeln!(s, "  sections:");
        for (name, d) in &self.sections {
            // chars().take, not byte slicing: report can render manifests
            // that never went through digest verification
            let head: String = d.sha256.chars().take(16).collect();
            let _ = writeln!(s, "    {:24} {:>10} B  sha256 {head}…", name, d.bytes);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::QuantMode;

    fn sample() -> ArtifactManifest {
        let policy = DiscretePolicy {
            layers: vec![
                LayerCmp { kept_channels: 8, quant: QuantMode::Fp32 },
                LayerCmp { kept_channels: 6, quant: QuantMode::Int8 },
                LayerCmp {
                    kept_channels: 4,
                    quant: QuantMode::Mix { w_bits: 4, a_bits: 6 },
                },
            ],
        };
        let policy_hash = policy_hash(&policy);
        let mut sections = BTreeMap::new();
        sections.insert(
            "stem.w".to_string(),
            SectionDigest { sha256: "ab".repeat(32), bytes: 1234 },
        );
        ArtifactManifest {
            schema_version: ARTIFACT_SCHEMA_VERSION,
            variant: "tiny".into(),
            layer_names: vec!["stem".into(), "b0".into(), "fc".into()],
            policy,
            policy_hash,
            target: "raspberry-pi-4b/cortex-a72".into(),
            target_fingerprint: "0123456789abcdef".into(),
            claim: LatencyClaim {
                latency_s: 1.5e-3,
                base_latency_s: 4.0e-3,
                backend: "sim".into(),
            },
            provenance: Provenance {
                weights: "synthetic:00000000deadbeef".into(),
                profile_cache: "none".into(),
                profile_schema_version: 1,
                tool: "galen test".into(),
            },
            sections,
        }
    }

    #[test]
    fn json_roundtrip_is_lossless_and_canonical() {
        let m = sample();
        let text = m.to_json().pretty(0);
        let back = ArtifactManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.to_json().pretty(0), text);
    }

    #[test]
    fn policy_hash_tracks_policy_content() {
        let m = sample();
        let mut other = m.policy.clone();
        other.layers[0].kept_channels = 7;
        assert_ne!(policy_hash(&m.policy), policy_hash(&other));
        assert_eq!(policy_hash(&m.policy), m.policy_hash);
    }

    #[test]
    fn from_json_reports_missing_fields_structurally() {
        let e = ArtifactManifest::from_json(&Json::parse("{}").unwrap()).unwrap_err();
        assert!(matches!(e, ArtifactError::Manifest(_)));
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn table_mentions_claims_and_provenance() {
        let t = sample().table();
        assert!(t.contains("claimed latency"));
        assert!(t.contains("synthetic:00000000deadbeef"));
        assert!(t.contains("MIX(w4/a6)"));
    }
}
