//! Packing: policy + weights → a deterministic `.galen` artifact.
//!
//! The packer slices each layer's weight tensor down to the policy's kept
//! channels (output channels by the same ℓ1 keep-first ranking the search
//! uses — `compress::l1_channel_ranking` — input channels following the
//! producer's kept set, exactly like `DiscretePolicy::effective_cin`),
//! then stores it per quant mode:
//!
//! * `FP32`  → `<layer>.w` (f32, sliced HWIO/IO shape);
//! * `INT8` / `MIX` → `<layer>.w_q` (symmetric per-output-channel i8 via
//!   `tensor::quant::QuantizedMat`, MIX clamped to its narrower
//!   `w_bits` grid) + `<layer>.w_scales` (one f32 per kept channel);
//! * pruned layers additionally carry `<layer>.kept_idx` (i32, ascending
//!   original output-channel indices) so a consumer can place the kept
//!   filters in the uncompressed coordinate system.
//!
//! Everything downstream of the inputs is a pure function: same IR,
//! policy and weights → byte-identical artifact (RNG only enters through
//! [`synthetic_weights`], itself a pure function of the variant name), so
//! artifacts are diffable, cacheable and content-addressable.
//!
//! Container layout (integers little-endian):
//!
//! ```text
//! magic  b"GLNART1\n"                              8 bytes
//! u64    manifest length; canonical manifest JSON  (see `manifest`)
//! u64    payload length; payload container         (see `payload`)
//! u8     signature flag (0 | 1)
//! [32]   HMAC-SHA256(key, manifest bytes) when flagged
//! 32     SHA-256 over every preceding byte
//! ```
//!
//! The trailing checksum makes any single-byte corruption detectable; the
//! optional HMAC authenticates the manifest (and, transitively through the
//! manifest's section digests, the payload) against deliberate tampering
//! by re-encoders who can recompute the plain checksum but not the keyed
//! signature.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::compress::{l1_channel_ranking, DiscretePolicy, QuantMode};
use crate::hw::HwTarget;
use crate::model::{Layer, LayerKind, ModelIr};
use crate::tensor::quant::QuantizedMat;
use crate::tensor::Mat;
use crate::util::json::{cleanup_stale_temps, write_bytes_atomic};
use crate::util::rng::Pcg64;
use crate::util::Fnv1a;

use super::hash;
use super::manifest::{
    policy_hash, ArtifactManifest, LatencyClaim, Provenance, SectionDigest,
    ARTIFACT_SCHEMA_VERSION,
};
use super::payload::{encode_section, Payload, SectionData};
use super::ARTIFACT_MAGIC;

/// Weight tensors by parameter name (`<layer>.w` → shape + f32 data), the
/// same view `runtime::ArtifactRegistry::params_by_name` exposes.
pub type WeightMap = BTreeMap<String, (Vec<usize>, Vec<f32>)>;

/// Everything [`pack`] consumes.
pub struct PackInputs<'a> {
    /// Structural IR of the model being packaged.
    pub ir: &'a ModelIr,
    /// The discretized policy to bake in.
    pub policy: &'a DiscretePolicy,
    /// Weight tensors (`<layer>.w` entries; extra names are ignored).
    pub weights: &'a WeightMap,
    /// Provenance label for the weights (`gten:<path>` / `synthetic:<hex>`).
    pub weights_source: String,
    /// Hardware target the latency claim refers to.
    pub target: &'a HwTarget,
    /// The claimed latency with backend label.
    pub claim: LatencyClaim,
    /// Profile-cache root label for provenance (`none` for sim).
    pub profile_cache: String,
}

/// A packed artifact: manifest + payload, ready to encode or write.
#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    /// The schema-versioned manifest.
    pub manifest: ArtifactManifest,
    /// The binary section container the manifest's digests cover.
    pub payload: Payload,
}

/// Build the artifact for `inputs.policy`.  Deterministic; fails (with
/// context) on missing weights, shape mismatches, or an invalid claim —
/// never on a well-formed session.
pub fn pack(inputs: &PackInputs<'_>) -> Result<Artifact> {
    let _sp = crate::obs::trace::span("artifact_pack")
        .arg("variant", inputs.ir.variant.clone());
    let ir = inputs.ir;
    let policy = inputs.policy;
    anyhow::ensure!(
        policy.layers.len() == ir.layers.len(),
        "policy has {} layers, IR '{}' has {}",
        policy.layers.len(),
        ir.variant,
        ir.layers.len()
    );
    anyhow::ensure!(
        inputs.claim.latency_s.is_finite()
            && inputs.claim.latency_s > 0.0
            && inputs.claim.base_latency_s.is_finite()
            && inputs.claim.base_latency_s > 0.0,
        "latency claim must be finite and positive (got {} / base {})",
        inputs.claim.latency_s,
        inputs.claim.base_latency_s
    );

    // pass 1: kept output channels per layer (ℓ1 keep-first, stored in
    // ascending original-index order — canonical and mask-equivalent)
    let mut kept_out: Vec<Vec<usize>> = Vec::with_capacity(ir.layers.len());
    for (l, cmp) in ir.layers.iter().zip(&policy.layers) {
        let (shape, w) = layer_weight(inputs.weights, l)?;
        anyhow::ensure!(
            (1..=l.cout).contains(&cmp.kept_channels),
            "layer {}: kept_channels {} outside 1..={}",
            l.name,
            cmp.kept_channels,
            l.cout
        );
        let mut keep: Vec<usize> =
            l1_channel_ranking(w, shape).into_iter().take(cmp.kept_channels).collect();
        keep.sort_unstable();
        kept_out.push(keep);
    }

    // pass 2: slice + quantize into payload sections
    let mut payload = Payload::default();
    for (i, (l, cmp)) in ir.layers.iter().zip(&policy.layers).enumerate() {
        let (shape, w) = layer_weight(inputs.weights, l)?;
        let keep = &kept_out[i];
        let (ci, co) = match l.kind {
            LayerKind::Conv => (shape[2], shape[3]),
            LayerKind::Linear => (shape[0], shape[1]),
        };
        let spatial = w.len() / (ci * co); // kernel^2 for convs, 1 otherwise
        let kept_in: Vec<usize> = match ir.producer_of(i) {
            // depthwise filters have a single input plane; the channel
            // coupling to the producer lives in the output-channel axis
            _ if ci == 1 => vec![0],
            Some(p) => {
                anyhow::ensure!(
                    ci == ir.layers[p].cout,
                    "layer {}: weight input dim {ci} does not match producer {} cout {}",
                    l.name,
                    ir.layers[p].name,
                    ir.layers[p].cout
                );
                kept_out[p].clone()
            }
            None => (0..ci).collect(),
        };
        let mut sliced = Vec::with_capacity(spatial * kept_in.len() * keep.len());
        for s in 0..spatial {
            for &cin in &kept_in {
                for &cout in keep {
                    sliced.push(w[(s * ci + cin) * co + cout]);
                }
            }
        }
        let sliced_shape = match l.kind {
            LayerKind::Conv => vec![l.kernel, l.kernel, kept_in.len(), keep.len()],
            LayerKind::Linear => vec![kept_in.len(), keep.len()],
        };
        match cmp.quant {
            QuantMode::Fp32 => {
                payload.insert(&format!("{}.w", l.name), sliced_shape, SectionData::F32(sliced));
            }
            mode => {
                let m = Mat::from_vec(spatial * kept_in.len(), keep.len(), sliced);
                let q = QuantizedMat::quantize_per_channel_qmax(&m, weight_qmax(mode));
                payload.insert(&format!("{}.w_q", l.name), sliced_shape, SectionData::I8(q.data));
                payload.insert(
                    &format!("{}.w_scales", l.name),
                    vec![keep.len()],
                    SectionData::F32(q.scales),
                );
            }
        }
        if keep.len() < l.cout {
            payload.insert(
                &format!("{}.kept_idx", l.name),
                vec![keep.len()],
                SectionData::I32(keep.iter().map(|&c| c as i32).collect()),
            );
        }
    }

    let manifest = ArtifactManifest {
        schema_version: ARTIFACT_SCHEMA_VERSION,
        variant: ir.variant.clone(),
        layer_names: ir.layers.iter().map(|l| l.name.clone()).collect(),
        policy: policy.clone(),
        policy_hash: policy_hash(policy),
        target: inputs.target.name.clone(),
        target_fingerprint: inputs.target.fingerprint_hex(),
        claim: inputs.claim.clone(),
        provenance: Provenance {
            weights: inputs.weights_source.clone(),
            profile_cache: inputs.profile_cache.clone(),
            profile_schema_version: crate::hw::PROFILE_SCHEMA_VERSION,
            tool: format!("galen {}", env!("CARGO_PKG_VERSION")),
        },
        sections: section_digests(&payload),
    };
    super::obs_packaged().inc();
    Ok(Artifact { manifest, payload })
}

impl Artifact {
    /// Canonical byte encoding; with `hmac_key`, the manifest is signed.
    pub fn encode(&self, hmac_key: Option<&[u8]>) -> Vec<u8> {
        let mut manifest_bytes = self.manifest.to_json().pretty(0).into_bytes();
        manifest_bytes.push(b'\n'); // `head -c` friendliness
        let payload_bytes = self.payload.to_bytes();
        let mut out = Vec::with_capacity(manifest_bytes.len() + payload_bytes.len() + 128);
        out.extend_from_slice(&ARTIFACT_MAGIC);
        out.extend_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
        out.extend_from_slice(&manifest_bytes);
        out.extend_from_slice(&(payload_bytes.len() as u64).to_le_bytes());
        out.extend_from_slice(&payload_bytes);
        match hmac_key {
            Some(key) => {
                out.push(1);
                out.extend_from_slice(&hash::hmac_sha256(key, &manifest_bytes));
            }
            None => out.push(0),
        }
        let checksum = hash::sha256(&out);
        out.extend_from_slice(&checksum);
        out
    }

    /// Write the encoded artifact durably: reap orphaned temps from a
    /// previous crash, then temp-file + fsync + atomic rename via
    /// `util::json::write_bytes_atomic` — a reader never observes a torn
    /// `.galen` file.
    pub fn write(&self, path: &Path, hmac_key: Option<&[u8]>) -> Result<()> {
        cleanup_stale_temps(path);
        write_bytes_atomic(path, &self.encode(hmac_key))
    }
}

/// Content digests of every payload section (the manifest's hash-tree
/// middle layer).
pub fn section_digests(payload: &Payload) -> BTreeMap<String, SectionDigest> {
    payload
        .sections
        .iter()
        .map(|(name, sec)| {
            let enc = encode_section(name, sec);
            (
                name.clone(),
                SectionDigest {
                    sha256: hash::hex(&hash::sha256(&enc)),
                    bytes: enc.len() as u64,
                },
            )
        })
        .collect()
}

/// The symmetric-quantization ceiling for a weight grid of `mode`:
/// 127 for INT8, `2^(w_bits-1) - 1` for MIX (min 1).
pub fn weight_qmax(mode: QuantMode) -> i32 {
    let (w_bits, _) = mode.bits();
    if w_bits >= 8 {
        127
    } else {
        ((1i32 << (w_bits.max(1) - 1)) - 1).max(1)
    }
}

/// `<variant>-<policyhash>.galen` — the artifact file name.
pub fn file_name(variant: &str, policy_hash: &str) -> String {
    format!("{variant}-{policy_hash}.galen")
}

/// Canonical output path `root/<sanitized target>/<variant>-<hash>.galen`
/// (the same per-target directory sanitization the profile and sweep
/// stores use).
pub fn artifact_path(
    root: &Path,
    target: &HwTarget,
    variant: &str,
    policy: &DiscretePolicy,
) -> PathBuf {
    root.join(crate::hw::sanitize(&target.name))
        .join(file_name(variant, &policy_hash(policy)))
}

/// The expected weight-tensor shape of a layer (HWIO for convs — one
/// input plane for depthwise — `[cin, cout]` for linear), matching the
/// AOT artifact manifests and the model zoo.
pub fn weight_shape(l: &Layer) -> Vec<usize> {
    match l.kind {
        LayerKind::Conv if l.depthwise => vec![l.kernel, l.kernel, 1, l.cout],
        LayerKind::Conv => vec![l.kernel, l.kernel, l.cin, l.cout],
        LayerKind::Linear => vec![l.cin, l.cout],
    }
}

/// Deterministic synthetic weights for sessions without AOT-exported
/// tensors: per-layer Kaiming-uniform-style values from a PCG stream
/// seeded purely by `(variant, layer name)` — two processes packaging the
/// same variant produce bit-identical tensors, which the artifact
/// format's byte-identical guarantee builds on.
pub fn synthetic_weights(ir: &ModelIr) -> WeightMap {
    let seed = synthetic_seed(&ir.variant);
    let mut out = BTreeMap::new();
    for l in &ir.layers {
        let shape = weight_shape(l);
        let numel: usize = shape.iter().product();
        let fan_in = (numel / l.cout).max(1) as f32;
        let lim = (1.0 / fan_in).sqrt();
        let mut h = Fnv1a::seeded(seed);
        h.mix_bytes(l.name.as_bytes());
        let mut rng = Pcg64::new(h.finish());
        let data: Vec<f32> = (0..numel).map(|_| (rng.next_f32() * 2.0 - 1.0) * lim).collect();
        out.insert(format!("{}.w", l.name), (shape, data));
    }
    out
}

/// The seed [`synthetic_weights`] derives everything from — recorded in
/// the manifest's provenance as `synthetic:<this, in hex>`.
pub fn synthetic_seed(variant: &str) -> u64 {
    let mut h = Fnv1a::new();
    h.mix_bytes(b"galen.artifact.synthetic-weights");
    h.mix_bytes(variant.as_bytes());
    h.finish()
}

fn layer_weight<'w>(weights: &'w WeightMap, l: &Layer) -> Result<(&'w [usize], &'w [f32])> {
    let key = format!("{}.w", l.name);
    let (shape, w) = weights
        .get(&key)
        .ok_or_else(|| anyhow::anyhow!("no weight tensor '{key}' to package"))?;
    let expect = weight_shape(l);
    anyhow::ensure!(
        *shape == expect,
        "weight '{key}' has shape {shape:?}, expected {expect:?}"
    );
    anyhow::ensure!(
        w.len() == expect.iter().product::<usize>(),
        "weight '{key}' data length {} does not match shape {shape:?}",
        w.len()
    );
    Ok((shape.as_slice(), w.as_slice()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::LayerCmp;
    use crate::model::ir::test_fixtures::tiny_meta;

    fn tiny() -> ModelIr {
        ModelIr::from_meta(&tiny_meta()).unwrap()
    }

    fn mixed_policy(ir: &ModelIr) -> DiscretePolicy {
        let mut p = DiscretePolicy::reference(ir);
        p.layers[1] = LayerCmp { kept_channels: 6, quant: QuantMode::Int8 };
        p.layers[3] = LayerCmp {
            kept_channels: 12,
            quant: QuantMode::Mix { w_bits: 4, a_bits: 6 },
        };
        p
    }

    fn inputs<'a>(
        ir: &'a ModelIr,
        policy: &'a DiscretePolicy,
        weights: &'a WeightMap,
        target: &'a HwTarget,
    ) -> PackInputs<'a> {
        PackInputs {
            ir,
            policy,
            weights,
            weights_source: format!("synthetic:{:016x}", synthetic_seed(&ir.variant)),
            target,
            claim: LatencyClaim {
                latency_s: 1.0e-3,
                base_latency_s: 2.0e-3,
                backend: "sim".into(),
            },
            profile_cache: "none".into(),
        }
    }

    #[test]
    fn pack_is_byte_identical_across_calls() {
        let ir = tiny();
        let policy = mixed_policy(&ir);
        let weights = synthetic_weights(&ir);
        let target = HwTarget::cortex_a72();
        let a = pack(&inputs(&ir, &policy, &weights, &target)).unwrap();
        let b = pack(&inputs(&ir, &policy, &weights, &target)).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.encode(None), b.encode(None));
        assert_eq!(a.encode(Some(b"k")), b.encode(Some(b"k")));
        // signing changes the bytes (flag + HMAC), not the manifest
        assert_ne!(a.encode(None), a.encode(Some(b"k")));
    }

    #[test]
    fn sections_follow_quant_modes_and_pruning() {
        let ir = tiny();
        let policy = mixed_policy(&ir);
        let weights = synthetic_weights(&ir);
        let target = HwTarget::cortex_a72();
        let art = pack(&inputs(&ir, &policy, &weights, &target)).unwrap();
        let s = &art.payload.sections;
        // fp32 layer keeps a plain weight section
        assert!(s.contains_key("stem.w") && !s.contains_key("stem.w_q"));
        // int8 layer gets quantized data + per-channel scales + kept_idx
        assert!(s.contains_key("s0b0.conv1.w_q"));
        assert_eq!(s["s0b0.conv1.w_scales"].shape, vec![6]);
        let SectionData::I32(idx) = &s["s0b0.conv1.kept_idx"].data else {
            panic!("kept_idx dtype");
        };
        assert_eq!(idx.len(), 6);
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "kept_idx ascending");
        // its consumer's input dim follows the producer's kept set
        assert_eq!(s["s0b0.conv2.w"].shape, vec![3, 3, 6, 8]);
        // every section is digested in the manifest
        assert_eq!(
            art.manifest.sections.keys().collect::<Vec<_>>(),
            s.keys().collect::<Vec<_>>()
        );
    }

    #[test]
    fn fp32_sections_are_bit_identical_slices_of_the_input() {
        let ir = tiny();
        let policy = DiscretePolicy::reference(&ir);
        let weights = synthetic_weights(&ir);
        let target = HwTarget::cortex_a72();
        let art = pack(&inputs(&ir, &policy, &weights, &target)).unwrap();
        // reference policy: no pruning, no quantization — the packaged
        // tensors must be the inputs, bit for bit
        for l in &ir.layers {
            let SectionData::F32(got) = &art.payload.sections[&format!("{}.w", l.name)].data
            else {
                panic!("dtype");
            };
            let (_, want) = &weights[&format!("{}.w", l.name)];
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits(), "layer {}", l.name);
            }
            assert!(!art.payload.sections.contains_key(&format!("{}.kept_idx", l.name)));
        }
    }

    #[test]
    fn mix_weights_respect_the_narrow_grid() {
        assert_eq!(weight_qmax(QuantMode::Int8), 127);
        assert_eq!(weight_qmax(QuantMode::Mix { w_bits: 4, a_bits: 4 }), 7);
        assert_eq!(weight_qmax(QuantMode::Mix { w_bits: 2, a_bits: 2 }), 1);
        let ir = tiny();
        let policy = mixed_policy(&ir);
        let weights = synthetic_weights(&ir);
        let target = HwTarget::cortex_a72();
        let art = pack(&inputs(&ir, &policy, &weights, &target)).unwrap();
        let SectionData::I8(q) = &art.payload.sections["s1b0.conv1.w_q"].data else {
            panic!("dtype");
        };
        assert!(q.iter().all(|&v| (-7..=7).contains(&v)), "4-bit grid");
        assert!(q.iter().any(|&v| v != 0));
    }

    #[test]
    fn pack_rejects_bad_inputs_with_context() {
        let ir = tiny();
        let weights = synthetic_weights(&ir);
        let target = HwTarget::cortex_a72();
        let mut policy = DiscretePolicy::reference(&ir);
        policy.layers.pop();
        let e = pack(&inputs(&ir, &policy, &weights, &target)).unwrap_err();
        assert!(format!("{e:#}").contains("layers"));

        let policy = DiscretePolicy::reference(&ir);
        let mut missing = weights.clone();
        missing.remove("fc.w");
        let e = pack(&inputs(&ir, &policy, &missing, &target)).unwrap_err();
        assert!(format!("{e:#}").contains("fc.w"));

        let mut bad_claim = inputs(&ir, &policy, &weights, &target);
        bad_claim.claim.latency_s = f64::NAN;
        assert!(pack(&bad_claim).is_err());
    }

    #[test]
    fn artifact_path_sanitizes_the_target_directory() {
        let ir = tiny();
        let policy = DiscretePolicy::reference(&ir);
        let p = artifact_path(Path::new("deploy"), &HwTarget::cortex_a72(), "tiny", &policy);
        let s = p.to_string_lossy();
        assert!(s.starts_with("deploy/raspberry-pi-4b-cortex-a72/tiny-"));
        assert!(s.ends_with(".galen"));
    }
}
