//! Loading and verifying `.galen` artifacts.
//!
//! Verification is strictly ordered, cheapest-and-outermost first, and
//! nothing is exposed to the caller until *every* applicable check has
//! passed — there is no partially-loaded artifact state:
//!
//! 1. container framing: magic, bounds-checked lengths, exact total size;
//! 2. whole-file SHA-256 checksum (catches any corruption byte);
//! 3. schema version, then full manifest parse;
//! 4. signature policy: HMAC verified when a key is supplied, presence
//!    enforced when required;
//! 5. payload container decode (structural);
//! 6. per-section content digests against the manifest (catches a
//!    re-encoded payload whose file checksum was recomputed);
//! 7. internal consistency: recomputed policy hash, finite positive
//!    claims, section/manifest key agreement.
//!
//! IR-dependent checks ([`check_against_ir`]) run separately because the
//! loader may not have a session yet — `galen run-artifact` opens its
//! session *from* the verified manifest's variant.
//!
//! Every failure is a structured [`ArtifactError`]; hostile bytes must
//! never panic (pinned by `tests/fuzz_artifact.rs`).

use std::path::Path;

use crate::compress::QuantMode;
use crate::model::ModelIr;

use super::hash;
use super::manifest::{policy_hash, ArtifactManifest, ARTIFACT_SCHEMA_VERSION};
use super::pack::{section_digests, weight_qmax};
use super::payload::{Payload, SectionData};
use super::{ArtifactError, ARTIFACT_MAGIC};

/// Signature policy for [`load_with`].
#[derive(Clone, Debug, Default)]
pub struct VerifyOptions {
    /// HMAC key: when set, a present signature must verify against it.
    pub hmac_key: Option<Vec<u8>>,
    /// Reject unsigned artifacts (deployment fleets set this).
    pub require_signature: bool,
}

/// A fully verified artifact.  Constructing one outside this module is
/// possible (the fields are public for packing and tests) but a loader
/// only ever returns instances whose every checksum passed.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadedArtifact {
    /// The verified manifest.
    pub manifest: ArtifactManifest,
    /// The verified payload.
    pub payload: Payload,
    /// Whether the artifact carried a signature that was verified against
    /// the supplied key.
    pub signature_verified: bool,
}

/// Load and fully verify an artifact file with default options (no key,
/// signatures optional).
pub fn load(path: &Path) -> Result<LoadedArtifact, ArtifactError> {
    load_with(path, &VerifyOptions::default())
}

/// Load and fully verify an artifact file.
pub fn load_with(path: &Path, opts: &VerifyOptions) -> Result<LoadedArtifact, ArtifactError> {
    // reap temps a crashed packager may have left next to the artifact
    crate::util::json::cleanup_stale_temps(path);
    let bytes = std::fs::read(path).map_err(|e| ArtifactError::Io {
        path: path.display().to_string(),
        source: e,
    })?;
    verify_bytes(&bytes, opts)
}

/// Verify an encoded artifact from memory (the file-free core of
/// [`load_with`]; what the fuzz harness drives).
pub fn verify_bytes(bytes: &[u8], opts: &VerifyOptions) -> Result<LoadedArtifact, ArtifactError> {
    let _sp = crate::obs::trace::span("artifact_verify");
    let r = verify_bytes_inner(bytes, opts);
    match &r {
        Ok(_) => super::obs_verify_ok().inc(),
        Err(e) => super::obs_verify_rejected(e.stage()).inc(),
    }
    r
}

fn verify_bytes_inner(
    bytes: &[u8],
    opts: &VerifyOptions,
) -> Result<LoadedArtifact, ArtifactError> {
    // 1. framing
    if bytes.len() < ARTIFACT_MAGIC.len() || bytes[..ARTIFACT_MAGIC.len()] != ARTIFACT_MAGIC {
        return Err(ArtifactError::BadMagic);
    }
    let header = |msg: String| ArtifactError::Header(msg);
    let need = |off: usize, n: usize| -> Result<&[u8], ArtifactError> {
        off.checked_add(n)
            .filter(|&e| e <= bytes.len())
            .map(|e| &bytes[off..e])
            .ok_or_else(|| header(format!("truncated at byte {off} (need {n} more)")))
    };
    let mut off = ARTIFACT_MAGIC.len();
    let manifest_len =
        u64::from_le_bytes(need(off, 8)?.try_into().unwrap()) as usize;
    off += 8;
    let manifest_bytes = need(off, manifest_len)?;
    off += manifest_len;
    let payload_len = u64::from_le_bytes(need(off, 8)?.try_into().unwrap()) as usize;
    off += 8;
    let payload_bytes = need(off, payload_len)?;
    off += payload_len;
    let sig_flag = need(off, 1)?[0];
    off += 1;
    let signature: Option<[u8; hash::DIGEST_LEN]> = match sig_flag {
        0 => None,
        1 => {
            let s = need(off, hash::DIGEST_LEN)?;
            off += hash::DIGEST_LEN;
            Some(s.try_into().unwrap())
        }
        other => return Err(header(format!("unknown signature flag {other}"))),
    };
    if bytes.len() != off + hash::DIGEST_LEN {
        return Err(header(format!(
            "file is {} bytes, framing implies {}",
            bytes.len(),
            off + hash::DIGEST_LEN
        )));
    }

    // 2. whole-file checksum (covers everything up to the trailer)
    let stored = &bytes[off..];
    let computed = hash::sha256(&bytes[..off]);
    if !hash::digest_eq(stored, &computed) {
        return Err(ArtifactError::Checksum {
            expected: hash::hex(stored),
            computed: hash::hex(&computed),
        });
    }

    // 3. schema version first (precise error for future formats), then
    // the full manifest parse
    let text = std::str::from_utf8(manifest_bytes)
        .map_err(|_| ArtifactError::Manifest("manifest is not UTF-8".into()))?;
    let doc = crate::util::json::Json::parse(text)
        .map_err(|e| ArtifactError::Manifest(format!("manifest parse: {e}")))?;
    let found = doc
        .req_usize("schema_version")
        .map_err(|e| ArtifactError::Manifest(format!("{e:#}")))?;
    if found != ARTIFACT_SCHEMA_VERSION {
        return Err(ArtifactError::SchemaVersion {
            found,
            supported: ARTIFACT_SCHEMA_VERSION,
        });
    }
    let manifest = ArtifactManifest::from_json(&doc)?;

    // 4. signature policy
    let signature_verified = match (&signature, &opts.hmac_key) {
        (None, _) if opts.require_signature => {
            return Err(ArtifactError::Signature("artifact is unsigned".into()));
        }
        (None, _) => false,
        (Some(_), None) => {
            // present but unverifiable without a key: only acceptable
            // when signatures are not required
            if opts.require_signature {
                return Err(ArtifactError::Signature(
                    "signature present but no key supplied to verify it".into(),
                ));
            }
            false
        }
        (Some(sig), Some(key)) => {
            let expect = hash::hmac_sha256(key, manifest_bytes);
            if !hash::digest_eq(sig, &expect) {
                return Err(ArtifactError::Signature(
                    "HMAC mismatch: manifest was altered or the key differs".into(),
                ));
            }
            true
        }
    };

    // 5. payload structure
    let payload = Payload::from_bytes(payload_bytes)?;

    // 6. per-section digests, both directions: every manifest digest must
    // match, and the payload may not smuggle undigested sections
    let computed = section_digests(&payload);
    for (name, want) in &manifest.sections {
        let Some(got) = computed.get(name) else {
            return Err(ArtifactError::Section {
                name: name.clone(),
                reason: "listed in the manifest but missing from the payload".into(),
            });
        };
        if got.bytes != want.bytes {
            return Err(ArtifactError::Section {
                name: name.clone(),
                reason: format!("{} encoded bytes, manifest says {}", got.bytes, want.bytes),
            });
        }
        if got.sha256 != want.sha256 {
            return Err(ArtifactError::Section {
                name: name.clone(),
                reason: format!(
                    "content hash {} does not match the manifest's {}",
                    got.sha256, want.sha256
                ),
            });
        }
    }
    for name in computed.keys() {
        if !manifest.sections.contains_key(name) {
            return Err(ArtifactError::Section {
                name: name.clone(),
                reason: "present in the payload but not digested by the manifest".into(),
            });
        }
    }

    // 7. internal consistency
    let recomputed = policy_hash(&manifest.policy);
    if recomputed != manifest.policy_hash {
        return Err(ArtifactError::Semantics(format!(
            "policy hash {} does not match the policy content ({recomputed})",
            manifest.policy_hash
        )));
    }
    if manifest.layer_names.len() != manifest.policy.layers.len() {
        return Err(ArtifactError::Semantics("layer name / policy length mismatch".into()));
    }
    if !(manifest.claim.latency_s.is_finite() && manifest.claim.latency_s > 0.0)
        || !(manifest.claim.base_latency_s.is_finite() && manifest.claim.base_latency_s > 0.0)
    {
        return Err(ArtifactError::Semantics(format!(
            "claimed latency must be finite and positive (got {} / base {})",
            manifest.claim.latency_s, manifest.claim.base_latency_s
        )));
    }

    Ok(LoadedArtifact {
        manifest,
        payload,
        signature_verified,
    })
}

/// Validate a verified artifact against a session's IR: layer names in
/// order, channel budgets, and the per-mode section inventory with
/// consistent shapes and value grids.  Run before executing or
/// re-measuring the policy.
pub fn check_against_ir(art: &LoadedArtifact, ir: &ModelIr) -> Result<(), ArtifactError> {
    let m = &art.manifest;
    let sem = |msg: String| ArtifactError::Semantics(msg);
    if m.variant != ir.variant {
        return Err(sem(format!(
            "artifact is for variant '{}', session IR is '{}'",
            m.variant, ir.variant
        )));
    }
    if m.layer_names.len() != ir.layers.len() {
        return Err(sem(format!(
            "artifact has {} layers, IR has {}",
            m.layer_names.len(),
            ir.layers.len()
        )));
    }
    for (l, (name, cmp)) in ir.layers.iter().zip(m.layer_names.iter().zip(&m.policy.layers)) {
        if *name != l.name {
            return Err(sem(format!("layer {} is '{name}' in the artifact, '{}' in the IR", l.index, l.name)));
        }
        if !(1..=l.cout).contains(&cmp.kept_channels) {
            return Err(sem(format!(
                "layer {}: kept_channels {} outside 1..={}",
                l.name, cmp.kept_channels, l.cout
            )));
        }
        let kept = cmp.kept_channels;
        let section = |suffix: &str| -> Result<&super::payload::Section, ArtifactError> {
            let key = format!("{}.{suffix}", l.name);
            art.payload.sections.get(&key).ok_or_else(|| ArtifactError::Section {
                name: key,
                reason: "required by the policy but absent".into(),
            })
        };
        let check_cout = |sec: &super::payload::Section, key: &str| {
            match sec.shape.last() {
                Some(&c) if c == kept => Ok(()),
                other => Err(ArtifactError::Section {
                    name: key.to_string(),
                    reason: format!(
                        "output-channel dim {other:?} does not match kept_channels {kept}"
                    ),
                }),
            }
        };
        match cmp.quant {
            QuantMode::Fp32 => {
                let sec = section("w")?;
                if !matches!(sec.data, SectionData::F32(_)) {
                    return Err(ArtifactError::Section {
                        name: format!("{}.w", l.name),
                        reason: "fp32 layer stored with a non-f32 section".into(),
                    });
                }
                check_cout(sec, &format!("{}.w", l.name))?;
            }
            mode => {
                let wq = section("w_q")?;
                let SectionData::I8(q) = &wq.data else {
                    return Err(ArtifactError::Section {
                        name: format!("{}.w_q", l.name),
                        reason: "quantized layer stored with a non-i8 section".into(),
                    });
                };
                check_cout(wq, &format!("{}.w_q", l.name))?;
                let qmax = weight_qmax(mode) as i8;
                if q.iter().any(|&v| v < -qmax || v > qmax) {
                    return Err(ArtifactError::Section {
                        name: format!("{}.w_q", l.name),
                        reason: format!("values exceed the ±{qmax} grid of {}", mode.label()),
                    });
                }
                let sc = section("w_scales")?;
                let SectionData::F32(scales) = &sc.data else {
                    return Err(ArtifactError::Section {
                        name: format!("{}.w_scales", l.name),
                        reason: "scales stored with a non-f32 section".into(),
                    });
                };
                if scales.len() != kept {
                    return Err(ArtifactError::Section {
                        name: format!("{}.w_scales", l.name),
                        reason: format!("{} scales for {kept} kept channels", scales.len()),
                    });
                }
                if scales.iter().any(|s| !s.is_finite() || *s <= 0.0) {
                    return Err(ArtifactError::Section {
                        name: format!("{}.w_scales", l.name),
                        reason: "scales must be finite and positive".into(),
                    });
                }
            }
        }
        if kept < l.cout {
            let sec = section("kept_idx")?;
            let SectionData::I32(idx) = &sec.data else {
                return Err(ArtifactError::Section {
                    name: format!("{}.kept_idx", l.name),
                    reason: "kept_idx stored with a non-i32 section".into(),
                });
            };
            let ascending_in_range = idx.len() == kept
                && idx.windows(2).all(|w| w[0] < w[1])
                && idx.iter().all(|&c| (0..l.cout as i32).contains(&c));
            if !ascending_in_range {
                return Err(ArtifactError::Section {
                    name: format!("{}.kept_idx", l.name),
                    reason: format!(
                        "must be {kept} strictly ascending indices below {}",
                        l.cout
                    ),
                });
            }
        }
    }
    Ok(())
}

/// Measured-vs-claimed latency comparison (`galen run-artifact`'s gate).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftReport {
    /// The manifest's claimed latency (seconds).
    pub claimed_s: f64,
    /// What this host just measured/simulated (seconds).
    pub measured_s: f64,
    /// Relative drift `|measured - claimed| / claimed`.
    pub drift: f64,
    /// The configured acceptance threshold on `drift`.
    pub tolerance: f64,
}

impl DriftReport {
    /// Compare `measured_s` against `claimed_s` under `tolerance`.
    pub fn new(claimed_s: f64, measured_s: f64, tolerance: f64) -> Self {
        let drift = if claimed_s > 0.0 {
            (measured_s - claimed_s).abs() / claimed_s
        } else {
            f64::INFINITY
        };
        Self {
            claimed_s,
            measured_s,
            drift,
            tolerance,
        }
    }

    /// Whether the measurement confirms the claim.
    pub fn within_tolerance(&self) -> bool {
        self.drift.is_finite() && self.drift <= self.tolerance
    }
}

impl std::fmt::Display for DriftReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "claimed {:.3} ms, measured {:.3} ms, drift {:.1}% (tolerance {:.1}%) — {}",
            self.claimed_s * 1e3,
            self.measured_s * 1e3,
            self.drift * 100.0,
            self.tolerance * 100.0,
            if self.within_tolerance() { "PASS" } else { "FAIL" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_report_gates_symmetrically() {
        let ok = DriftReport::new(1.0e-3, 1.1e-3, 0.25);
        assert!(ok.within_tolerance());
        assert!((ok.drift - 0.1).abs() < 1e-9);
        let slow = DriftReport::new(1.0e-3, 1.4e-3, 0.25);
        assert!(!slow.within_tolerance());
        // a *faster* measurement than claimed is drift too: the claim is
        // wrong either way, and fleets schedule against it
        let fast = DriftReport::new(1.0e-3, 0.5e-3, 0.25);
        assert!(!fast.within_tolerance());
        assert!(format!("{slow}").contains("FAIL"));
        assert!(format!("{ok}").contains("PASS"));
        assert!(!DriftReport::new(0.0, 1.0, 0.5).within_tolerance());
    }
}
