//! Deployable compression artifacts: a signed, checksummed container
//! that carries a discretized policy together with the weights it
//! prescribes, ready to hand to a device fleet.
//!
//! A search (or sweep, or serve job) ends with a policy and a latency
//! claim; everything else needed to reproduce that operating point on a
//! device — which channels survive, which layers quantize to what grid,
//! the per-channel scales, which hardware target the claim was profiled
//! on — lives in the session's caches.  The artifact freezes all of it
//! into one relocatable `.galen` file:
//!
//! ```text
//! "GLNART1\n"  (8 bytes)
//! manifest len (u64 LE) | manifest JSON          — schema-versioned
//! payload len  (u64 LE) | payload container      — see [`payload`]
//! sig flag (u8)         | HMAC-SHA256(key, manifest) when flag = 1
//! SHA-256 over every preceding byte (32 bytes)
//! ```
//!
//! Integrity forms a tree: the trailing checksum covers the whole file,
//! the manifest stores a digest of every payload section, and each
//! section encoding covers its own name/dtype/shape/data.  A flipped
//! bit anywhere is caught by at least one level; a *re-encoded* file
//! with a recomputed trailer is caught by the section digests (payload
//! edits) or the HMAC (manifest edits, when signed).  Encoding is
//! deterministic — same inputs, byte-identical artifact, regardless of
//! `GALEN_NUM_THREADS`.
//!
//! Module map: [`hash`] (SHA-256/HMAC), [`payload`] (tensor container),
//! [`manifest`] (schema + JSON), [`pack`] (policy+weights → artifact),
//! [`verify`] (untrusted bytes → [`verify::LoadedArtifact`]).

use std::sync::OnceLock;

use crate::obs;

pub mod hash;
pub mod manifest;
pub mod pack;
pub mod payload;
pub mod verify;

pub use manifest::{
    policy_hash, ArtifactManifest, LatencyClaim, Provenance, SectionDigest,
    ARTIFACT_SCHEMA_VERSION,
};
pub use pack::{artifact_path, pack, synthetic_weights, Artifact, PackInputs, WeightMap};
pub use payload::{Payload, Section, SectionData};
pub use verify::{
    check_against_ir, load, load_with, verify_bytes, DriftReport, LoadedArtifact, VerifyOptions,
};

/// Leading magic of an encoded artifact file.
pub const ARTIFACT_MAGIC: [u8; 8] = *b"GLNART1\n";

/// Why an artifact was rejected.  Every loader failure is one of these —
/// hostile input must produce a structured error, never a panic, and
/// never a partially-loaded artifact.
#[derive(Debug, thiserror::Error)]
pub enum ArtifactError {
    /// The file could not be read at all.
    #[error("artifact io at {path}: {source}")]
    Io {
        /// Path we attempted to read.
        path: String,
        /// Underlying filesystem error.
        #[source]
        source: std::io::Error,
    },
    /// The leading magic is wrong — not an artifact file.
    #[error("not a galen artifact (bad magic)")]
    BadMagic,
    /// The outer framing (lengths, flags, total size) is inconsistent.
    #[error("artifact framing: {0}")]
    Header(String),
    /// The trailing whole-file checksum does not match the content.
    #[error("artifact checksum mismatch: stored {expected}, computed {computed}")]
    Checksum {
        /// Digest stored in the file trailer.
        expected: String,
        /// Digest recomputed over the file body.
        computed: String,
    },
    /// The manifest failed to parse or is structurally invalid.
    #[error("artifact manifest: {0}")]
    Manifest(String),
    /// The manifest declares a schema this build does not speak.
    #[error("artifact schema version {found} unsupported (this build reads {supported})")]
    SchemaVersion {
        /// Version the file declares.
        found: usize,
        /// Version this build supports.
        supported: usize,
    },
    /// Signature policy violation: missing, unverifiable, or wrong HMAC.
    #[error("artifact signature: {0}")]
    Signature(String),
    /// The payload container is malformed.
    #[error("artifact payload: {0}")]
    Payload(String),
    /// A specific payload section is missing, undeclared, or corrupt.
    #[error("artifact section '{name}': {reason}")]
    Section {
        /// Section name (e.g. `s0b0.conv1.w_q`).
        name: String,
        /// What is wrong with it.
        reason: String,
    },
    /// Cross-field or artifact-vs-IR inconsistency.
    #[error("artifact semantics: {0}")]
    Semantics(String),
}

/// The fixed rejection-stage vocabulary, shared by [`ArtifactError::stage`]
/// and the labelled rejection counters.
const STAGES: [&str; 10] = [
    "io",
    "magic",
    "header",
    "checksum",
    "manifest",
    "schema",
    "signature",
    "payload",
    "section",
    "semantics",
];

impl ArtifactError {
    /// Which verification stage rejected the artifact (a stable label for
    /// metrics and for tests asserting *where* corruption was caught).
    pub fn stage(&self) -> &'static str {
        match self {
            ArtifactError::Io { .. } => STAGES[0],
            ArtifactError::BadMagic => STAGES[1],
            ArtifactError::Header(_) => STAGES[2],
            ArtifactError::Checksum { .. } => STAGES[3],
            ArtifactError::Manifest(_) => STAGES[4],
            ArtifactError::SchemaVersion { .. } => STAGES[5],
            ArtifactError::Signature(_) => STAGES[6],
            ArtifactError::Payload(_) => STAGES[7],
            ArtifactError::Section { .. } => STAGES[8],
            ArtifactError::Semantics(_) => STAGES[9],
        }
    }
}

pub(crate) fn obs_packaged() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("artifact_packaged_total", &[]))
}

pub(crate) fn obs_verify_ok() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("artifact_verify_total", &[("outcome", "ok")]))
}

/// Rejections labelled by the verification stage that caught them.
pub(crate) fn obs_verify_rejected(stage: &'static str) -> &'static obs::Counter {
    static C: OnceLock<[obs::Counter; STAGES.len()]> = OnceLock::new();
    let all = C.get_or_init(|| {
        STAGES.map(|s| obs::Counter::register("artifact_verify_rejected_total", &[("stage", s)]))
    });
    let idx = STAGES.iter().position(|s| *s == stage).unwrap_or(0);
    &all[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_error_maps_to_a_declared_stage() {
        let errs: Vec<ArtifactError> = vec![
            ArtifactError::Io {
                path: "x".into(),
                source: std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
            },
            ArtifactError::BadMagic,
            ArtifactError::Header("h".into()),
            ArtifactError::Checksum {
                expected: "a".into(),
                computed: "b".into(),
            },
            ArtifactError::Manifest("m".into()),
            ArtifactError::SchemaVersion {
                found: 9,
                supported: ARTIFACT_SCHEMA_VERSION,
            },
            ArtifactError::Signature("s".into()),
            ArtifactError::Payload("p".into()),
            ArtifactError::Section {
                name: "n".into(),
                reason: "r".into(),
            },
            ArtifactError::Semantics("z".into()),
        ];
        assert_eq!(errs.len(), STAGES.len());
        for (e, want) in errs.iter().zip(STAGES) {
            assert_eq!(e.stage(), want);
            // Display must mention enough to debug from a log line
            assert!(!format!("{e}").is_empty());
        }
    }
}
