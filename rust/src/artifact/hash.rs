//! Content hashing for artifacts: SHA-256 and HMAC-SHA256, from scratch.
//!
//! The crate-wide `util::Fnv1a` is a fine *fingerprint* (cache keys,
//! seeds) but far too weak for content integrity — a 64-bit non-crypto
//! hash cannot anchor the artifact's tamper-evidence story.  The offline
//! build pulls no crypto dependency, so this module implements FIPS 180-4
//! SHA-256 and RFC 2104 HMAC directly (~100 lines, verified against the
//! NIST / RFC 4231 test vectors below).
//!
//! Everything here is pure and allocation-free per block, so hashing is
//! deterministic across platforms and thread counts — a prerequisite for
//! the artifact format's byte-identical-output guarantee.

/// Digest width in bytes.
pub const DIGEST_LEN: usize = 32;

/// SHA-256 round constants (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Incremental SHA-256 state (init → `update`* → `finish`).
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh state with the FIPS 180-4 initial hash values.
    pub fn new() -> Self {
        Self {
            h: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
                0x1f83d9ab, 0x5be0cd19,
            ],
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    /// Absorb `data` (any length; buffers partial blocks).
    pub fn update(&mut self, data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        let mut rest = data;
        if self.buf_len > 0 {
            let take = rest.len().min(64 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while rest.len() >= 64 {
            let (block, tail) = rest.split_at(64);
            let mut b = [0u8; 64];
            b.copy_from_slice(block);
            self.compress(&b);
            rest = tail;
        }
        if !rest.is_empty() {
            self.buf[..rest.len()].copy_from_slice(rest);
            self.buf_len = rest.len();
        }
    }

    /// Pad, absorb the length, and return the 32-byte digest.
    pub fn finish(mut self) -> [u8; DIGEST_LEN] {
        let bit_len = self.total_len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // bypass update's length accounting for the trailer itself
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; DIGEST_LEN];
        for (chunk, h) in out.chunks_exact_mut(4).zip(self.h) {
            chunk.copy_from_slice(&h.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for t in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[t])
                .wrapping_add(w[t]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (hi, v) in self.h.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *hi = hi.wrapping_add(v);
        }
    }
}

/// One-shot SHA-256 of `data`.
pub fn sha256(data: &[u8]) -> [u8; DIGEST_LEN] {
    let mut s = Sha256::new();
    s.update(data);
    s.finish()
}

/// HMAC-SHA256 (RFC 2104): keyed authentication of `msg`.  Keys longer
/// than the 64-byte block are pre-hashed, shorter ones zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; DIGEST_LEN] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..DIGEST_LEN].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Sha256::new();
    let ipad: Vec<u8> = k.iter().map(|b| b ^ 0x36).collect();
    inner.update(&ipad);
    inner.update(msg);
    let inner_digest = inner.finish();
    let mut outer = Sha256::new();
    let opad: Vec<u8> = k.iter().map(|b| b ^ 0x5c).collect();
    outer.update(&opad);
    outer.update(&inner_digest);
    outer.finish()
}

/// Lowercase hex of a digest (the form manifests store).
pub fn hex(digest: &[u8]) -> String {
    let mut s = String::with_capacity(digest.len() * 2);
    for b in digest {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Constant-shape digest comparison.  Timing side channels are a
/// non-goal offline, but comparing full width unconditionally costs
/// nothing and avoids an accidental early-exit dependency on attacker
/// bytes.
pub fn digest_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    /// NIST FIPS 180-4 example vectors.
    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    /// Incremental updates across odd split points match the one-shot
    /// digest (the encoder hashes section-by-section).
    #[test]
    fn sha256_incremental_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let oneshot = sha256(&data);
        for split in [1, 7, 63, 64, 65, 500, 999] {
            let mut s = Sha256::new();
            s.update(&data[..split]);
            s.update(&data[split..]);
            assert_eq!(s.finish(), oneshot, "split at {split}");
        }
    }

    /// Million-'a' vector exercises many blocks through the buffer path.
    #[test]
    fn sha256_million_a() {
        let mut s = Sha256::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            s.update(&chunk);
        }
        assert_eq!(
            hex(&s.finish()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    /// RFC 4231 HMAC-SHA256 test cases 1, 2 and the long-key case 6.
    #[test]
    fn hmac_known_vectors() {
        assert_eq!(
            hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        assert_eq!(
            hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn digest_eq_rejects_any_difference() {
        let a = sha256(b"x");
        let mut b = a;
        assert!(digest_eq(&a, &b));
        b[31] ^= 1;
        assert!(!digest_eq(&a, &b));
        assert!(!digest_eq(&a, &a[..31]));
    }
}
