//! The artifact's binary payload: a deterministic named-section container.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic  b"GLNPAY1\n"                           8 bytes
//! u32    section count
//! per section, in strictly ascending name order:
//!   u16  name length, then the UTF-8 name bytes
//!   u8   dtype (0 = i8, 1 = f32, 2 = i32)
//!   u8   ndim, then ndim x u32 dims
//!   u64  raw data length in bytes (= numel x dtype size)
//!   raw  element data, little-endian
//! ```
//!
//! Sections live in a `BTreeMap`, so encoding is canonical: the same
//! tensors always serialize to the same bytes, and the decoder *rejects*
//! out-of-order or duplicate names rather than silently re-sorting — an
//! artifact either is in canonical form or is not an artifact.  f32 data
//! round-trips via `to_le_bytes`/`from_le_bytes`, so weights and scales
//! survive bit-exactly (the pack→unpack property test pins this).
//!
//! Decoding is strict and total: every length is bounds-checked against
//! the buffer, dimension products use checked arithmetic, and trailing
//! bytes are an error.  A hostile payload yields an
//! `ArtifactError::Payload`, never a panic or a partial container.

use std::collections::BTreeMap;

use super::ArtifactError;

/// Magic bytes opening an encoded payload.
pub const PAYLOAD_MAGIC: [u8; 8] = *b"GLNPAY1\n";

/// Most dimensions a section may declare (shapes here are ≤ 4-D HWIO).
pub const MAX_NDIM: usize = 8;

/// Raw element storage of one section.
#[derive(Clone, Debug, PartialEq)]
pub enum SectionData {
    /// Quantized weights (per-channel symmetric i8).
    I8(Vec<i8>),
    /// Full-precision weights and per-channel scales.
    F32(Vec<f32>),
    /// Index vectors (kept output-channel indices).
    I32(Vec<i32>),
}

impl SectionData {
    /// Wire dtype tag (0/1/2 = i8/f32/i32).
    pub fn dtype(&self) -> u8 {
        match self {
            SectionData::I8(_) => 0,
            SectionData::F32(_) => 1,
            SectionData::I32(_) => 2,
        }
    }

    /// Bytes per element for this dtype.
    pub fn elem_size(&self) -> usize {
        match self {
            SectionData::I8(_) => 1,
            SectionData::F32(_) | SectionData::I32(_) => 4,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            SectionData::I8(v) => v.len(),
            SectionData::F32(v) => v.len(),
            SectionData::I32(v) => v.len(),
        }
    }

    /// True when the section holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One named tensor in the payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Section {
    /// Tensor shape; the product must equal the data length.
    pub shape: Vec<usize>,
    /// Element storage.
    pub data: SectionData,
}

impl Section {
    /// Element count implied by the shape.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A canonical, ordered collection of named sections.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Payload {
    /// Sections by name (BTreeMap order == wire order).
    pub sections: BTreeMap<String, Section>,
}

impl Payload {
    /// Add a section, enforcing the shape/data consistency the encoder
    /// relies on.  Panics on programmer error (inconsistent shape), which
    /// can only originate in-process — decoded payloads go through the
    /// checked [`Payload::from_bytes`] path instead.
    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: SectionData) {
        let numel: usize = shape.iter().product();
        assert_eq!(numel, data.len(), "section '{name}': shape/data mismatch");
        assert!(shape.len() <= MAX_NDIM, "section '{name}': too many dims");
        let prev = self.sections.insert(name.to_string(), Section { shape, data });
        assert!(prev.is_none(), "section '{name}' inserted twice");
    }

    /// Canonical encoding of the whole container.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&PAYLOAD_MAGIC);
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, sec) in &self.sections {
            out.extend_from_slice(&encode_section(name, sec));
        }
        out
    }

    /// Strict decode; inverse of [`Payload::to_bytes`] on valid input,
    /// a structured [`ArtifactError::Payload`] on anything else.
    pub fn from_bytes(bytes: &[u8]) -> Result<Payload, ArtifactError> {
        let mut r = Reader { bytes, pos: 0 };
        let magic = r.take(8, "magic")?;
        if magic != PAYLOAD_MAGIC {
            return Err(err("bad payload magic"));
        }
        let count = u32::from_le_bytes(r.take(4, "section count")?.try_into().unwrap());
        let mut sections = BTreeMap::new();
        let mut last_name: Option<String> = None;
        for i in 0..count {
            let (name, sec) = decode_section(&mut r, i)?;
            if let Some(prev) = &last_name {
                if *prev >= name {
                    return Err(err(&format!(
                        "section '{name}' out of canonical order (after '{prev}')"
                    )));
                }
            }
            last_name = Some(name.clone());
            sections.insert(name, sec);
        }
        if r.pos != bytes.len() {
            return Err(err(&format!(
                "{} trailing bytes after the last section",
                bytes.len() - r.pos
            )));
        }
        Ok(Payload { sections })
    }
}

/// Canonical encoding of one named section — also the unit the manifest's
/// per-section content hashes cover, so a digest protects the name, dtype,
/// shape *and* data of its section.
pub fn encode_section(name: &str, sec: &Section) -> Vec<u8> {
    assert!(name.len() <= u16::MAX as usize, "section name too long");
    assert!(
        sec.shape.iter().all(|&d| d <= u32::MAX as usize),
        "section '{name}': dimension exceeds u32"
    );
    let mut out = Vec::new();
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out.push(sec.data.dtype());
    out.push(sec.shape.len() as u8);
    for &d in &sec.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    let byte_len = (sec.data.len() * sec.data.elem_size()) as u64;
    out.extend_from_slice(&byte_len.to_le_bytes());
    match &sec.data {
        SectionData::I8(v) => out.extend(v.iter().map(|&x| x as u8)),
        SectionData::F32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        SectionData::I32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

fn decode_section(r: &mut Reader<'_>, index: u32) -> Result<(String, Section), ArtifactError> {
    let ctx = format!("section #{index}");
    let name_len = u16::from_le_bytes(r.take(2, &ctx)?.try_into().unwrap()) as usize;
    let name = std::str::from_utf8(r.take(name_len, &ctx)?)
        .map_err(|_| err(&format!("{ctx}: name is not UTF-8")))?
        .to_string();
    if name.is_empty() {
        return Err(err(&format!("{ctx}: empty name")));
    }
    let dtype = r.take(1, &name)?[0];
    let ndim = r.take(1, &name)?[0] as usize;
    if ndim > MAX_NDIM {
        return Err(err(&format!("section '{name}': {ndim} dims (max {MAX_NDIM})")));
    }
    let mut shape = Vec::with_capacity(ndim);
    let mut numel: usize = 1;
    for _ in 0..ndim {
        let d = u32::from_le_bytes(r.take(4, &name)?.try_into().unwrap()) as usize;
        numel = numel
            .checked_mul(d)
            .ok_or_else(|| err(&format!("section '{name}': shape overflows")))?;
        shape.push(d);
    }
    let byte_len = u64::from_le_bytes(r.take(8, &name)?.try_into().unwrap());
    let elem_size: usize = match dtype {
        0 => 1,
        1 | 2 => 4,
        other => return Err(err(&format!("section '{name}': unknown dtype {other}"))),
    };
    let expect = numel
        .checked_mul(elem_size)
        .ok_or_else(|| err(&format!("section '{name}': byte length overflows")))?;
    if byte_len != expect as u64 {
        return Err(err(&format!(
            "section '{name}': declares {byte_len} data bytes, shape implies {expect}"
        )));
    }
    let raw = r.take(expect, &name)?;
    let data = match dtype {
        0 => SectionData::I8(raw.iter().map(|&b| b as i8).collect()),
        1 => SectionData::F32(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        _ => SectionData::I32(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
    };
    Ok((name, Section { shape, data }))
}

fn err(msg: &str) -> ArtifactError {
    ArtifactError::Payload(msg.to_string())
}

/// Bounds-checked forward reader over the encoded bytes.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], ArtifactError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| err(&format!("truncated reading {what} ({n} bytes)")))?;
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Payload {
        let mut p = Payload::default();
        p.insert("a.w_q", vec![2, 3], SectionData::I8(vec![1, -2, 3, -4, 5, -128]));
        p.insert("a.w_scales", vec![3], SectionData::F32(vec![0.5, -0.0, 1.5e-3]));
        p.insert("a.kept_idx", vec![2], SectionData::I32(vec![0, 7]));
        p
    }

    #[test]
    fn roundtrip_bit_exact() {
        let p = sample();
        let bytes = p.to_bytes();
        let q = Payload::from_bytes(&bytes).unwrap();
        assert_eq!(p, q);
        // and the re-encode is byte-identical (canonical form)
        assert_eq!(q.to_bytes(), bytes);
    }

    #[test]
    fn f32_payload_preserves_sign_and_subnormals() {
        let mut p = Payload::default();
        let vals = vec![-0.0f32, f32::MIN_POSITIVE / 2.0, 1.0e-40, 3.4e38];
        p.insert("w", vec![4], SectionData::F32(vals.clone()));
        let q = Payload::from_bytes(&p.to_bytes()).unwrap();
        let SectionData::F32(got) = &q.sections["w"].data else {
            panic!("dtype changed");
        };
        for (a, b) in vals.iter().zip(got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn truncation_at_every_boundary_is_an_error() {
        let bytes = sample().to_bytes();
        for cut in 0..bytes.len() {
            let e = Payload::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(matches!(e, ArtifactError::Payload(_)), "cut at {cut}: {e}");
        }
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_magic() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(Payload::from_bytes(&bytes).is_err());
        let mut bad = sample().to_bytes();
        bad[0] ^= 0xff;
        assert!(Payload::from_bytes(&bad).is_err());
    }

    #[test]
    fn rejects_non_canonical_order() {
        // hand-build "b" before "a": decoder must refuse to re-sort
        let mut one = Payload::default();
        one.insert("b", vec![1], SectionData::I8(vec![1]));
        let mut two = Payload::default();
        two.insert("a", vec![1], SectionData::I8(vec![2]));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&PAYLOAD_MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&encode_section("b", &one.sections["b"]));
        bytes.extend_from_slice(&encode_section("a", &two.sections["a"]));
        let e = Payload::from_bytes(&bytes).unwrap_err();
        assert!(format!("{e}").contains("canonical order"));
    }

    #[test]
    fn rejects_shape_data_mismatch_and_unknown_dtype() {
        let sec = Section { shape: vec![3], data: SectionData::I8(vec![1, 2, 3]) };
        let mut enc = encode_section("w", &sec);
        // corrupt the declared byte length (u64 right before the 3 data bytes)
        let len_off = enc.len() - 3 - 8;
        enc[len_off] = 99;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&PAYLOAD_MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&enc);
        assert!(Payload::from_bytes(&bytes).is_err());

        let mut enc2 = encode_section("w", &sec);
        let dtype_off = 2 + 1; // u16 name len + name "w"
        enc2[dtype_off] = 9; // unknown dtype
        let mut bytes2 = Vec::new();
        bytes2.extend_from_slice(&PAYLOAD_MAGIC);
        bytes2.extend_from_slice(&1u32.to_le_bytes());
        bytes2.extend_from_slice(&enc2);
        let e = Payload::from_bytes(&bytes2).unwrap_err();
        assert!(format!("{e}").contains("unknown dtype"));
    }
}
