//! Model intermediate representation.
//!
//! Rust never re-derives the network from Python — it loads the structural
//! manifest (`artifacts/meta_<variant>.json`) emitted at AOT time and builds
//! a graph-level IR: layer shapes, MACs/BOPs accounting, and the pruning
//! *dependency groups* that make residual-coupled layers non-prunable
//! (paper: "we automatically detect such dependencies ... and do not accept
//! the prediction of pruning parameters for affected layers").

/// Graph-level IR with MAC/BOP accounting and dependency groups.
pub mod ir;
/// Manifest loader (`meta_<variant>.json`).
pub mod meta;
/// Built-in model zoo: manifests constructed in Rust (no artifacts needed).
pub mod zoo;

pub use ir::{Layer, LayerKind, ModelIr};
pub use meta::{load_meta, ManifestEntry, ModelMeta};
