//! Graph-level IR over the manifest, with MAC/BOP accounting and
//! pruning-dependency groups.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::meta::ModelMeta;

/// Operator class of a compressible layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv,
    /// Fully-connected layer.
    Linear,
}

/// One compressible layer of the model (conv or linear).
#[derive(Clone, Debug)]
pub struct Layer {
    /// Position in the IR's layer list.
    pub index: usize,
    /// Layer name (matches the artifact manifests).
    pub name: String,
    /// Conv or linear.
    pub kind: LayerKind,
    /// Input channels (original).
    pub cin: usize,
    /// Output channels (original).
    pub cout: usize,
    /// Square kernel extent (1 for linear).
    pub kernel: usize,
    /// Stride (1 for linear).
    pub stride: usize,
    /// Input spatial extent (square).
    pub in_spatial: usize,
    /// Output spatial extent (square).
    pub out_spatial: usize,
    /// Independently prunable (not residual-coupled).
    pub prunable: bool,
    /// Dependency group id (>= 0 couples the layer to a residual stream).
    pub group: i64,
    /// Whether the conv is depthwise.
    pub depthwise: bool,
}

impl Layer {
    /// MACs at the layer's *original* configuration.
    pub fn macs(&self) -> u64 {
        self.macs_at(self.cin, self.cout)
    }

    /// MACs with compressed channel counts.
    ///
    /// Depthwise convs apply one k x k filter per channel instead of a full
    /// cin x cout cross product: their MAC count scales with the surviving
    /// channel count `min(cin, cout)` (a depthwise layer is structurally
    /// square, and under pruning its width follows its producer — the
    /// `min` keeps probed asymmetric configurations conservative).
    pub fn macs_at(&self, cin: usize, cout: usize) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                (self.kernel as u64)
                    * (self.kernel as u64)
                    * self.channel_product(cin, cout)
                    * (self.out_spatial as u64)
                    * (self.out_spatial as u64)
            }
            LayerKind::Linear => cin as u64 * cout as u64,
        }
    }

    /// Parameter count (weights only) with compressed channels.  Depthwise
    /// filter banks hold one k x k plane per surviving channel.
    pub fn params_at(&self, cin: usize, cout: usize) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                (self.kernel * self.kernel) as u64 * self.channel_product(cin, cout)
            }
            LayerKind::Linear => (cin * cout) as u64,
        }
    }

    /// The channel term of conv MAC/parameter accounting: `cin * cout` for
    /// dense convs, the surviving channel count for depthwise ones.
    fn channel_product(&self, cin: usize, cout: usize) -> u64 {
        if self.depthwise {
            cin.min(cout) as u64
        } else {
            cin as u64 * cout as u64
        }
    }

    /// Output activation element count per sample with `cout` channels.
    pub fn out_elems(&self, cout: usize) -> u64 {
        (self.out_spatial * self.out_spatial * cout) as u64
    }

    /// Input activation element count per sample with `cin` channels.
    pub fn in_elems(&self, cin: usize) -> u64 {
        (self.in_spatial * self.in_spatial * cin) as u64
    }
}

/// The full compressible-model IR.
#[derive(Clone, Debug)]
pub struct ModelIr {
    /// Model variant name (`micro`/`resnet18s`/...).
    pub variant: String,
    /// Input image extent (square).
    pub img: usize,
    /// Classifier output count.
    pub classes: usize,
    /// Compressible layers in forward order.
    pub layers: Vec<Layer>,
    /// group id -> member layer indices (residual streams).
    pub groups: BTreeMap<i64, Vec<usize>>,
    /// For layer i, the set of layer indices whose *input* channel count
    /// follows layer i's output channels (consumers).
    pub consumers: Vec<Vec<usize>>,
    /// policy-input name -> position in the policy manifest (input packing).
    pub policy_index: BTreeMap<String, usize>,
    /// Test accuracy of the uncompressed model (from the manifest).
    pub base_test_acc: f64,
    /// Evaluation batch size of the artifact.
    pub eval_batch: usize,
    /// Retraining batch size of the artifact.
    pub train_batch: usize,
}

impl ModelIr {
    /// Build the IR from a parsed manifest (validates kinds and groups).
    pub fn from_meta(meta: &ModelMeta) -> Result<Self> {
        let mut layers = Vec::with_capacity(meta.layers.len());
        for (i, l) in meta.layers.iter().enumerate() {
            let kind = match l.kind.as_str() {
                "conv" => LayerKind::Conv,
                "linear" => LayerKind::Linear,
                k => bail!("unknown layer kind '{k}'"),
            };
            layers.push(Layer {
                index: i,
                name: l.name.clone(),
                kind,
                cin: l.cin,
                cout: l.cout,
                kernel: l.kernel,
                stride: l.stride,
                in_spatial: l.in_spatial,
                out_spatial: l.out_spatial,
                prunable: l.prunable,
                group: l.group,
                depthwise: l.depthwise,
            });
        }

        let mut groups: BTreeMap<i64, Vec<usize>> = BTreeMap::new();
        for l in &layers {
            if l.group >= 0 {
                groups.entry(l.group).or_default().push(l.index);
            }
        }
        // dependency sanity: group members share the output width
        for (gid, members) in &groups {
            let w = layers[members[0]].cout;
            if members.iter().any(|&i| layers[i].cout != w) {
                bail!("group {gid} members disagree on width");
            }
        }

        // Consumers: topology-specific wiring for the ResNet family. A
        // conv1 feeds the following conv2; stream members feed the next
        // stage's first conv1/downsample and (last stream) the classifier.
        let consumers = Self::infer_consumers(&layers);

        let policy_index = meta
            .policy
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();

        Ok(Self {
            variant: meta.variant.clone(),
            img: meta.img,
            classes: meta.classes,
            layers,
            groups,
            consumers,
            policy_index,
            base_test_acc: meta.base_test_acc,
            eval_batch: meta.eval_batch,
            train_batch: meta.train_batch,
        })
    }

    /// Wire up who consumes whose output channels, from the layer list
    /// (manifest order is forward order).  Block-internal chains follow the
    /// naming convention: conv1 -> its block's conv2 (ResNet family) and
    /// expand -> dw -> project (MobileNet family); an independent conv with
    /// no chain successor (the MobileNet `head`) feeds later linear layers
    /// of matching width (the classifier).  A stream member (group >= 0)
    /// feeds every later layer that *enters* a block — any conv that is
    /// not itself a chain successor (conv1/down/expand/head-style), plus
    /// linear layers — whose input width equals the stream width: stage
    /// widths are unique within a family (the zoo asserts streams never
    /// collide with expanded widths), so the width identifies the stream.
    fn infer_consumers(layers: &[Layer]) -> Vec<Vec<usize>> {
        /// Block-internal successor suffixes: who a `group < 0` layer feeds.
        const CHAIN: &[(&str, &str)] =
            &[(".conv1", ".conv2"), (".expand", ".dw"), (".dw", ".project")];
        /// A chain successor reads its block-internal producer, never a
        /// residual stream directly.
        fn is_chain_successor(name: &str) -> bool {
            CHAIN.iter().any(|(_, to)| name.ends_with(to))
        }
        let mut consumers = vec![Vec::new(); layers.len()];
        for (i, l) in layers.iter().enumerate() {
            if l.group < 0 {
                let successor = CHAIN.iter().find_map(|(from, to)| {
                    l.name
                        .strip_suffix(from)
                        .map(|prefix| format!("{prefix}{to}"))
                });
                match successor {
                    Some(target) => {
                        if let Some(j) = layers.iter().position(|m| m.name == target) {
                            consumers[i].push(j);
                        }
                    }
                    None => {
                        // chainless independent conv (MobileNet head): its
                        // readers are later linear layers of matching width
                        for (j, m) in layers.iter().enumerate().skip(i + 1) {
                            if m.kind == LayerKind::Linear && m.cin == l.cout {
                                consumers[i].push(j);
                            }
                        }
                    }
                }
                continue;
            }
            for (j, m) in layers.iter().enumerate().skip(i + 1) {
                let enters_a_block = match m.kind {
                    LayerKind::Linear => true,
                    LayerKind::Conv => !is_chain_successor(&m.name),
                };
                if enters_a_block && m.cin == l.cout {
                    consumers[i].push(j);
                }
            }
        }
        consumers
    }

    /// Find a layer by its manifest name.
    pub fn layer_by_name(&self, name: &str) -> Option<&Layer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// The producer whose output channels layer `i` reads: the first layer
    /// listing `i` among its consumers (`None` for graph inputs).  This is
    /// the lookup `DiscretePolicy::effective_cin` and the depthwise
    /// coupling checks share, so the first-match convention lives in
    /// exactly one place.
    pub fn producer_of(&self, i: usize) -> Option<usize> {
        self.consumers.iter().position(|cs| cs.contains(&i))
    }

    /// Total MACs at the original configuration (per sample).
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Total parameters at the original configuration.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params_at(l.cin, l.cout)).sum()
    }

    /// Indices of layers the pruning agent may act on.
    pub fn prunable_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter(|l| l.prunable)
            .map(|l| l.index)
            .collect()
    }

    /// Number of compressible layers (= time steps per episode).
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Position of a policy input in the flat policy vector, by name.
    pub fn policy_pos(&self, name: &str) -> Option<usize> {
        self.policy_index.get(name).copied()
    }
}

/// Artifact-free test fixtures (also used by benches and examples).
pub mod test_fixtures {
    //! Artifact-free fixtures: a miniature ResNet-shaped manifest used by
    //! unit tests, property tests and microbenches that must not depend on
    //! `artifacts/` being built.
    use super::super::meta::{ManifestEntry, MetaLayer, ModelMeta};

    /// A miniature ResNet-shaped manifest (stem + one block per 2 stages +
    /// fc) for tests that must not depend on artifacts/ being built.
    pub fn tiny_meta() -> ModelMeta {
        let conv = |name: &str, cin, cout, k, stride, isp, osp, prunable, group| MetaLayer {
            name: name.into(),
            kind: "conv".into(),
            cin,
            cout,
            kernel: k,
            stride,
            in_spatial: isp,
            out_spatial: osp,
            prunable,
            group,
            depthwise: false,
        };
        let layers = vec![
            conv("stem", 3, 8, 3, 1, 16, 16, false, 0),
            conv("s0b0.conv1", 8, 8, 3, 1, 16, 16, true, -1),
            conv("s0b0.conv2", 8, 8, 3, 1, 16, 16, false, 0),
            conv("s1b0.conv1", 8, 16, 3, 2, 16, 8, true, -1),
            conv("s1b0.conv2", 16, 16, 3, 1, 8, 8, false, 1),
            conv("s1b0.down", 8, 16, 1, 2, 16, 8, false, 1),
            MetaLayer {
                name: "fc".into(),
                kind: "linear".into(),
                cin: 16,
                cout: 10,
                kernel: 1,
                stride: 1,
                in_spatial: 1,
                out_spatial: 1,
                prunable: false,
                group: -1,
                depthwise: false,
            },
        ];
        let mut params = Vec::new();
        let mut policy = Vec::new();
        for l in &layers {
            if l.kind == "conv" {
                params.push(ManifestEntry {
                    name: format!("{}.w", l.name),
                    shape: vec![l.kernel, l.kernel, l.cin, l.cout],
                    trainable: true,
                });
                for p in ["gamma", "beta", "mean", "var"] {
                    params.push(ManifestEntry {
                        name: format!("{}.bn.{p}", l.name),
                        shape: vec![l.cout],
                        trainable: p == "gamma" || p == "beta",
                    });
                }
                policy.push(ManifestEntry {
                    name: format!("{}.mask", l.name),
                    shape: vec![l.cout],
                    trainable: false,
                });
                for p in ["w_bits", "a_bits"] {
                    policy.push(ManifestEntry {
                        name: format!("{}.{p}", l.name),
                        shape: vec![],
                        trainable: false,
                    });
                }
            }
        }
        params.push(ManifestEntry {
            name: "fc.w".into(),
            shape: vec![16, 10],
            trainable: true,
        });
        params.push(ManifestEntry {
            name: "fc.b".into(),
            shape: vec![10],
            trainable: true,
        });
        policy.push(ManifestEntry {
            name: "fc.w_bits".into(),
            shape: vec![],
            trainable: false,
        });
        policy.push(ManifestEntry {
            name: "fc.a_bits".into(),
            shape: vec![],
            trainable: false,
        });
        let trainable = params
            .iter()
            .enumerate()
            .filter(|(_, e)| e.trainable)
            .map(|(i, _)| i)
            .collect();
        ModelMeta {
            variant: "tiny".into(),
            img: 16,
            classes: 10,
            width: 8,
            blocks: vec![1, 1],
            eval_batch: 8,
            train_batch: 4,
            base_test_acc: 0.9,
            layers,
            params,
            policy,
            trainable,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_fixtures::tiny_meta;
    use super::*;

    fn ir() -> ModelIr {
        ModelIr::from_meta(&tiny_meta()).unwrap()
    }

    #[test]
    fn builds_groups() {
        let ir = ir();
        assert_eq!(ir.groups[&0], vec![0, 2]); // stem + s0b0.conv2
        assert_eq!(ir.groups[&1], vec![4, 5]); // s1b0.conv2 + down
    }

    #[test]
    fn mac_accounting() {
        let ir = ir();
        let stem = &ir.layers[0];
        assert_eq!(stem.macs(), 3 * 3 * 3 * 8 * 16 * 16);
        let fc = ir.layers.last().unwrap();
        assert_eq!(fc.macs(), 160);
        assert_eq!(
            ir.total_macs(),
            ir.layers.iter().map(|l| l.macs()).sum::<u64>()
        );
        // pruning cuts MACs linearly in cout
        let l = &ir.layers[1];
        assert_eq!(l.macs_at(l.cin, l.cout / 2) * 2, l.macs());
    }

    #[test]
    fn prunable_set() {
        let ir = ir();
        let p = ir.prunable_layers();
        assert_eq!(p, vec![1, 3]); // the two conv1 layers
    }

    #[test]
    fn consumers_wiring() {
        let ir = ir();
        // conv1 -> conv2 of the same block
        assert_eq!(ir.consumers[1], vec![2]);
        assert_eq!(ir.consumers[3], vec![4]);
        // stage-0 stream members feed stage-1 conv1 and down
        assert!(ir.consumers[0].contains(&3) && ir.consumers[0].contains(&5));
        assert!(ir.consumers[2].contains(&3) && ir.consumers[2].contains(&5));
        // stage-1 stream feeds the classifier
        assert!(ir.consumers[4].contains(&6));
    }

    #[test]
    fn policy_positions() {
        let ir = ir();
        assert_eq!(ir.policy_pos("stem.mask"), Some(0));
        assert_eq!(ir.policy_pos("stem.w_bits"), Some(1));
        assert_eq!(ir.policy_pos("fc.a_bits"), Some(ir.policy_index.len() - 1));
        assert_eq!(ir.policy_pos("nope"), None);
    }

    #[test]
    fn rejects_inconsistent_group() {
        let mut meta = tiny_meta();
        meta.layers[2].cout = 4; // break group width invariant
        assert!(ModelIr::from_meta(&meta).is_err());
    }

    #[test]
    fn depthwise_mac_and_param_accounting() {
        let mut meta = tiny_meta();
        // turn s0b0.conv1 (8 -> 8, 3x3 @ 16) into a depthwise conv
        meta.layers[1].depthwise = true;
        let ir = ModelIr::from_meta(&meta).unwrap();
        let l = &ir.layers[1];
        assert_eq!(l.macs(), 3 * 3 * 8 * 16 * 16, "k^2 * C * osp^2");
        assert_eq!(l.params_at(l.cin, l.cout), 3 * 3 * 8);
        // one-eighth of the dense layer's MACs (C vs C*C channels)
        let mut dense = meta.clone();
        dense.layers[1].depthwise = false;
        let dense_ir = ModelIr::from_meta(&dense).unwrap();
        assert_eq!(dense_ir.layers[1].macs(), 8 * l.macs());
        // asymmetric probes use the surviving channel count
        assert_eq!(l.macs_at(4, 8), l.macs_at(8, 4));
        assert_eq!(l.macs_at(4, 8) * 2, l.macs_at(8, 8));
    }

    #[test]
    fn mobilenet_consumer_wiring() {
        let meta = crate::model::zoo::meta("mobilenetv2s").unwrap();
        let ir = ModelIr::from_meta(&meta).unwrap();
        let idx = |name: &str| ir.layer_by_name(name).unwrap().index;
        // block-internal chain: expand -> dw -> project
        assert_eq!(ir.consumers[idx("s0b0.expand")], vec![idx("s0b0.dw")]);
        assert_eq!(ir.consumers[idx("s0b0.dw")], vec![idx("s0b0.project")]);
        // the stage-0 stream (stem + s0b0.project) feeds both stage-0/1
        // expands that read width 16
        for p in [idx("stem"), idx("s0b0.project")] {
            assert!(ir.consumers[p].contains(&idx("s1b0.expand")), "{p}");
        }
        // the last stream feeds the head, the head feeds the classifier
        assert!(ir.consumers[idx("s2b1.project")].contains(&idx("head")));
        assert_eq!(ir.consumers[idx("head")], vec![idx("fc")]);
        // producer_of inverts the wiring (what effective_cin relies on)
        assert_eq!(ir.producer_of(idx("s0b0.dw")), Some(idx("s0b0.expand")));
        assert_eq!(ir.producer_of(idx("s0b0.project")), Some(idx("s0b0.dw")));
        assert_eq!(ir.producer_of(idx("fc")), Some(idx("head")));
        assert_eq!(ir.producer_of(idx("stem")), None, "graph input has no producer");
        // depthwise convs never read a residual stream directly
        for (p, cs) in ir.consumers.iter().enumerate() {
            if ir.layers[p].group >= 0 {
                assert!(
                    cs.iter().all(|&j| !ir.layers[j].depthwise),
                    "stream member {} wired into a depthwise conv",
                    ir.layers[p].name
                );
            }
        }
    }
}
