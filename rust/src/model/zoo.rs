//! Built-in model zoo: structural manifests constructed in Rust.
//!
//! Synthetic-backend sessions only need a [`ModelMeta`] — the layer graph,
//! pruning-dependency groups, and the parameter/policy input contract — not
//! trained weights.  This module constructs those manifests directly, so
//! `galen search --synthetic --variant mobilenetv2s` (and sweeps, serve,
//! benches, tests) run without `python/compile/aot.py` ever having been
//! invoked.  When `artifacts/meta_<variant>.json` *does* exist it still
//! wins (it carries the trained `base_test_acc`); the zoo is the fallback —
//! see `coordinator::Session::open`.
//!
//! Two model families:
//!
//! * **ResNet family** (`micro`, `resnet18s`, `resnet18`) — byte-for-byte
//!   the same layer graph `python/compile/model.py::conv_specs` emits:
//!   3x3 stem, stages of BasicBlocks, residual streams as dependency
//!   groups, each block's conv1 independently prunable.
//! * **MobileNetV2 family** (`mobilenetv2s`) — inverted-residual blocks of
//!   expand (1x1) / depthwise (3x3, `depthwise: true`) / project (1x1)
//!   convs sized for CIFAR-10.  The expanded inner width is the prunable
//!   axis (the analogue of ResNet's conv1); the depthwise conv is
//!   channel-coupled to its expand producer (its width *follows* — it is
//!   never independently prunable, see `agent::PruningMapper`); every
//!   project output joins its stage's residual stream group.  This is the
//!   first built-in workload whose per-layer compression trade-offs differ
//!   qualitatively from ResNet's: depthwise layers carry k^2-per-channel
//!   MACs (not k^2 * cin * cout), are excluded from mixed precision by the
//!   bit-serial operator constraints, and are memory- rather than
//!   compute-bound on the target.

use anyhow::{bail, Result};

use super::meta::{ManifestEntry, MetaLayer, ModelMeta};

/// Variants the zoo can construct (the CLI `--variant` values that work
/// without artifacts; `tiny` additionally exists as the in-code test
/// fixture, see `model::ir::test_fixtures`).
pub const VARIANTS: &[&str] = &["micro", "resnet18s", "resnet18", "mobilenetv2s"];

/// Whether `variant` is a zoo model.
pub fn has_variant(variant: &str) -> bool {
    VARIANTS.contains(&variant)
}

/// Construct the structural manifest of a zoo variant.
///
/// `base_test_acc` is a nominal placeholder (the synthetic accuracy proxy
/// normalizes against it); artifact manifests written by `aot.py` carry the
/// actually-trained accuracy and take precedence when present.
pub fn meta(variant: &str) -> Result<ModelMeta> {
    match variant {
        "micro" => Ok(resnet_meta("micro", 8, &[1, 1, 1, 1], 0.88)),
        "resnet18s" => Ok(resnet_meta("resnet18s", 32, &[2, 2, 2, 2], 0.92)),
        "resnet18" => Ok(resnet_meta("resnet18", 64, &[2, 2, 2, 2], 0.93)),
        "mobilenetv2s" => Ok(mobilenet_meta()),
        other => bail!(
            "unknown zoo variant '{other}' (built-in: {})",
            VARIANTS.join(", ")
        ),
    }
}

const IMG: usize = 32;
const CLASSES: usize = 10;
const EVAL_BATCH: usize = 128;
const TRAIN_BATCH: usize = 64;

#[allow(clippy::too_many_arguments)]
fn conv_layer(
    name: String,
    cin: usize,
    cout: usize,
    kernel: usize,
    stride: usize,
    in_spatial: usize,
    out_spatial: usize,
    prunable: bool,
    group: i64,
    depthwise: bool,
) -> MetaLayer {
    MetaLayer {
        name,
        kind: "conv".into(),
        cin,
        cout,
        kernel,
        stride,
        in_spatial,
        out_spatial,
        prunable,
        group,
        depthwise,
    }
}

/// Append the conv's parameter entries (weight + BN) and policy entries
/// (mask + bit scalars) in the artifact order `model.py` emits.
fn push_conv_manifests(
    l: &MetaLayer,
    params: &mut Vec<ManifestEntry>,
    policy: &mut Vec<ManifestEntry>,
) {
    // depthwise filters have one k x k plane per channel (HWIO with I = 1)
    let w_shape = if l.depthwise {
        vec![l.kernel, l.kernel, 1, l.cout]
    } else {
        vec![l.kernel, l.kernel, l.cin, l.cout]
    };
    params.push(ManifestEntry {
        name: format!("{}.w", l.name),
        shape: w_shape,
        trainable: true,
    });
    for (p, trainable) in [("gamma", true), ("beta", true), ("mean", false), ("var", false)] {
        params.push(ManifestEntry {
            name: format!("{}.bn.{p}", l.name),
            shape: vec![l.cout],
            trainable,
        });
    }
    policy.push(ManifestEntry {
        name: format!("{}.mask", l.name),
        shape: vec![l.cout],
        trainable: false,
    });
    for p in ["w_bits", "a_bits"] {
        policy.push(ManifestEntry {
            name: format!("{}.{p}", l.name),
            shape: vec![],
            trainable: false,
        });
    }
}

/// Finish a manifest: append the classifier entries and derive `trainable`.
fn finish_meta(
    variant: &str,
    width: usize,
    blocks: Vec<usize>,
    base_test_acc: f64,
    mut layers: Vec<MetaLayer>,
    fc_cin: usize,
) -> ModelMeta {
    layers.push(MetaLayer {
        name: "fc".into(),
        kind: "linear".into(),
        cin: fc_cin,
        cout: CLASSES,
        kernel: 1,
        stride: 1,
        in_spatial: 1,
        out_spatial: 1,
        prunable: false,
        group: -1,
        depthwise: false,
    });
    let mut params = Vec::new();
    let mut policy = Vec::new();
    for l in &layers {
        if l.kind == "conv" {
            push_conv_manifests(l, &mut params, &mut policy);
        }
    }
    params.push(ManifestEntry {
        name: "fc.w".into(),
        shape: vec![fc_cin, CLASSES],
        trainable: true,
    });
    params.push(ManifestEntry {
        name: "fc.b".into(),
        shape: vec![CLASSES],
        trainable: true,
    });
    policy.push(ManifestEntry {
        name: "fc.w_bits".into(),
        shape: vec![],
        trainable: false,
    });
    policy.push(ManifestEntry {
        name: "fc.a_bits".into(),
        shape: vec![],
        trainable: false,
    });
    let trainable = params
        .iter()
        .enumerate()
        .filter(|(_, e)| e.trainable)
        .map(|(i, _)| i)
        .collect();
    ModelMeta {
        variant: variant.into(),
        img: IMG,
        classes: CLASSES,
        width,
        blocks,
        eval_batch: EVAL_BATCH,
        train_batch: TRAIN_BATCH,
        base_test_acc,
        layers,
        params,
        policy,
        trainable,
    }
}

/// The ResNet family: the exact layer graph `model.py::conv_specs` emits.
/// Group g_i is the residual stream of stage i (stem or downsample plus
/// every block's conv2); each block's conv1 is independently prunable.
fn resnet_meta(variant: &str, width: usize, blocks: &[usize], base_test_acc: f64) -> ModelMeta {
    let widths: Vec<usize> = (0..blocks.len()).map(|i| width << i).collect();
    let mut layers = Vec::new();
    let mut sp = IMG;
    layers.push(conv_layer("stem".into(), 3, widths[0], 3, 1, sp, sp, false, 0, false));
    let mut cin = widths[0];
    for (si, (&w, &nb)) in widths.iter().zip(blocks).enumerate() {
        let stage_stride = if si == 0 { 1 } else { 2 };
        for bi in 0..nb {
            let s = if bi == 0 { stage_stride } else { 1 };
            let out_sp = sp / s;
            let name = format!("s{si}b{bi}");
            layers.push(conv_layer(
                format!("{name}.conv1"),
                cin,
                w,
                3,
                s,
                sp,
                out_sp,
                true,
                -1,
                false,
            ));
            layers.push(conv_layer(
                format!("{name}.conv2"),
                w,
                w,
                3,
                1,
                out_sp,
                out_sp,
                false,
                si as i64,
                false,
            ));
            if bi == 0 && (s != 1 || cin != w) {
                layers.push(conv_layer(
                    format!("{name}.down"),
                    cin,
                    w,
                    1,
                    s,
                    sp,
                    out_sp,
                    false,
                    si as i64,
                    false,
                ));
            }
            cin = w;
            sp = out_sp;
        }
    }
    finish_meta(variant, width, blocks.to_vec(), base_test_acc, layers, cin)
}

/// MobileNetV2-small for CIFAR-10: stem 3x3, three stages of
/// inverted-residual blocks (expansion 4), a 1x1 head conv, classifier.
///
/// Stage widths are 16 / 24 / 48 with 1 / 2 / 2 blocks; the expanded inner
/// widths (64 / 96 / 192) are deliberately distinct from every stream
/// width, so the width-identifies-the-stream consumer wiring of
/// `ModelIr::infer_consumers` stays unambiguous (same invariant the ResNet
/// family relies on).
fn mobilenet_meta() -> ModelMeta {
    /// Channel expansion factor t of every inverted-residual block.
    const EXPANSION: usize = 4;
    let stage_widths: [usize; 3] = [16, 24, 48];
    let stage_blocks: [usize; 3] = [1, 2, 2];
    let head_cout = 96;

    let mut layers = Vec::new();
    let mut sp = IMG;
    layers.push(conv_layer("stem".into(), 3, stage_widths[0], 3, 1, sp, sp, false, 0, false));
    let mut cin = stage_widths[0];
    for (si, (&w, &nb)) in stage_widths.iter().zip(&stage_blocks).enumerate() {
        let stage_stride = if si == 0 { 1 } else { 2 };
        for bi in 0..nb {
            let s = if bi == 0 { stage_stride } else { 1 };
            let out_sp = sp / s;
            let name = format!("s{si}b{bi}");
            let e = EXPANSION * cin;
            // expand: the prunable inner width (the conv1 analogue)
            layers.push(conv_layer(
                format!("{name}.expand"),
                cin,
                e,
                1,
                1,
                sp,
                sp,
                true,
                -1,
                false,
            ));
            // depthwise: channel-coupled to the expand producer — its
            // width follows the expand's pruning decision, so it is never
            // independently prunable
            layers.push(conv_layer(
                format!("{name}.dw"),
                e,
                e,
                3,
                s,
                sp,
                out_sp,
                false,
                -1,
                true,
            ));
            // project: writes the stage's residual stream (group si), so
            // all projects of a stage share one channel mask
            layers.push(conv_layer(
                format!("{name}.project"),
                e,
                w,
                1,
                1,
                out_sp,
                out_sp,
                false,
                si as i64,
                false,
            ));
            cin = w;
            sp = out_sp;
        }
    }
    // head: independently prunable 1x1 feeding the classifier
    layers.push(conv_layer(
        "head".into(),
        cin,
        head_cout,
        1,
        1,
        sp,
        sp,
        true,
        -1,
        false,
    ));
    finish_meta(
        "mobilenetv2s",
        stage_widths[0],
        stage_blocks.to_vec(),
        0.91,
        layers,
        head_cout,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelIr;

    #[test]
    fn every_variant_builds_a_valid_ir() {
        for v in VARIANTS {
            let meta = meta(v).unwrap();
            let ir = ModelIr::from_meta(&meta).unwrap_or_else(|e| panic!("{v}: {e:#}"));
            assert_eq!(&ir.variant, v);
            assert!(ir.total_macs() > 0);
            assert!(!ir.prunable_layers().is_empty(), "{v} has no prunable layers");
            // the policy manifest covers every conv mask + all bit scalars
            let convs = ir
                .layers
                .iter()
                .filter(|l| l.kind == crate::model::LayerKind::Conv)
                .count();
            assert_eq!(ir.policy_index.len(), 3 * convs + 2, "{v} policy manifest");
        }
        assert!(meta("nope").is_err());
        assert!(has_variant("mobilenetv2s") && !has_variant("tiny"));
    }

    #[test]
    fn resnet_family_matches_the_python_generator_shape() {
        // micro: stem + 4 stages x 1 block x (conv1+conv2) + 3 downsamples
        // (stages 1..3 change stride/width) + fc = 1 + 8 + 3 + 1 = 13
        let micro = meta("micro").unwrap();
        assert_eq!(micro.layers.len(), 13);
        // resnet18s: stem + 8 blocks x 2 + 3 downsamples + fc = 21
        let r18s = meta("resnet18s").unwrap();
        assert_eq!(r18s.layers.len(), 21);
        assert_eq!(r18s.layers[0].cout, 32);
        let fc = r18s.layers.last().unwrap();
        assert_eq!((fc.cin, fc.cout), (256, 10));
        // stage-0 has no downsample (stride 1, equal widths)
        assert!(!r18s.layers.iter().any(|l| l.name == "s0b0.down"));
        assert!(r18s.layers.iter().any(|l| l.name == "s1b0.down"));
        // no ResNet layer is depthwise
        assert!(r18s.layers.iter().all(|l| !l.depthwise));
    }

    #[test]
    fn mobilenet_blocks_are_expand_dw_project() {
        let m = meta("mobilenetv2s").unwrap();
        let ir = ModelIr::from_meta(&m).unwrap();
        // stem + 5 blocks x 3 + head + fc
        assert_eq!(ir.layers.len(), 1 + 5 * 3 + 1 + 1);
        let dw: Vec<_> = ir.layers.iter().filter(|l| l.depthwise).collect();
        assert_eq!(dw.len(), 5, "one depthwise conv per block");
        for l in &dw {
            assert!(l.name.ends_with(".dw"));
            assert_eq!(l.cin, l.cout, "depthwise convs are square");
            assert_eq!(l.kernel, 3);
            assert!(!l.prunable, "depthwise width follows its expand producer");
            assert!(l.group < 0, "depthwise convs are not stream members");
        }
        // expanded widths: 4x the block input
        let e = ir.layer_by_name("s0b0.expand").unwrap();
        assert_eq!((e.cin, e.cout), (16, 64));
        assert!(e.prunable);
        let e = ir.layer_by_name("s2b1.expand").unwrap();
        assert_eq!((e.cin, e.cout), (48, 192));
        // spatial schedule: 32 -> 16 (stage 1) -> 8 (stage 2)
        assert_eq!(ir.layer_by_name("s1b0.dw").unwrap().out_spatial, 16);
        assert_eq!(ir.layer_by_name("s2b0.dw").unwrap().out_spatial, 8);
        // head feeds the classifier
        let head = ir.layer_by_name("head").unwrap();
        assert!(head.prunable);
        assert_eq!(ir.layers.last().unwrap().cin, head.cout);
    }

    #[test]
    fn mobilenet_groups_are_per_stage_streams() {
        let ir = ModelIr::from_meta(&meta("mobilenetv2s").unwrap()).unwrap();
        // group 0: stem + s0b0.project (width 16)
        let names = |g: i64| -> Vec<&str> {
            ir.groups[&g].iter().map(|&i| ir.layers[i].name.as_str()).collect()
        };
        assert_eq!(names(0), vec!["stem", "s0b0.project"]);
        assert_eq!(names(1), vec!["s1b0.project", "s1b1.project"]);
        assert_eq!(names(2), vec!["s2b0.project", "s2b1.project"]);
        // stream widths must be distinct from every expanded width (the
        // consumer wiring identifies streams by width)
        let stream_widths: Vec<usize> =
            ir.groups.values().map(|m| ir.layers[m[0]].cout).collect();
        for l in ir.layers.iter().filter(|l| l.name.ends_with(".expand")) {
            assert!(
                !stream_widths.contains(&l.cout),
                "expanded width {} collides with a stream width",
                l.cout
            );
        }
    }

    #[test]
    fn mobilenet_depthwise_macs_are_not_dense_macs() {
        let ir = ModelIr::from_meta(&meta("mobilenetv2s").unwrap()).unwrap();
        for l in ir.layers.iter().filter(|l| l.depthwise) {
            let dense = 9 * (l.cin as u64) * (l.cout as u64)
                * (l.out_spatial as u64)
                * (l.out_spatial as u64);
            assert!(l.macs() < dense, "{}: dw {} vs dense {}", l.name, l.macs(), dense);
            assert_eq!(
                l.macs(),
                9 * l.cout as u64 * (l.out_spatial as u64) * (l.out_spatial as u64)
            );
        }
    }
}
