//! Loader for the AOT structural manifest (`artifacts/meta_<variant>.json`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One entry of the parameter or policy input manifest: the artifact input
/// contract (name, shape, position = index in the list).
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    /// Input name (e.g. `stem.w`, `stem.mask`).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Whether retraining updates it.
    pub trainable: bool,
}

/// Raw layer description straight from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct MetaLayer {
    /// Layer name.
    pub name: String,
    /// `"conv"` or `"linear"`.
    pub kind: String,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Square kernel extent.
    pub kernel: usize,
    /// Stride.
    pub stride: usize,
    /// Input spatial extent.
    pub in_spatial: usize,
    /// Output spatial extent.
    pub out_spatial: usize,
    /// Independently prunable.
    pub prunable: bool,
    /// Residual dependency group (-1 = none).
    pub group: i64,
    /// Depthwise convolution flag.
    pub depthwise: bool,
}

/// Everything `aot.py` recorded about one exported model variant.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Variant name.
    pub variant: String,
    /// Input image extent.
    pub img: usize,
    /// Classifier output count.
    pub classes: usize,
    /// Base channel width.
    pub width: usize,
    /// Residual blocks per stage.
    pub blocks: Vec<usize>,
    /// Evaluation batch size.
    pub eval_batch: usize,
    /// Retraining batch size.
    pub train_batch: usize,
    /// Test accuracy of the uncompressed model.
    pub base_test_acc: f64,
    /// Layer descriptions in forward order.
    pub layers: Vec<MetaLayer>,
    /// Parameter input manifest (artifact argument order).
    pub params: Vec<ManifestEntry>,
    /// Policy input manifest (artifact argument order).
    pub policy: Vec<ManifestEntry>,
    /// Indices of trainable parameter entries.
    pub trainable: Vec<usize>,
}

fn entry(j: &Json) -> Result<ManifestEntry> {
    let shape = j
        .req_arr("shape")?
        .iter()
        .map(|d| d.as_usize().context("shape dim not a number"))
        .collect::<Result<Vec<_>>>()?;
    Ok(ManifestEntry {
        name: j.req_str("name")?.to_string(),
        shape,
        trainable: j.get("trainable").and_then(Json::as_bool).unwrap_or(false),
    })
}

fn layer(j: &Json) -> Result<MetaLayer> {
    Ok(MetaLayer {
        name: j.req_str("name")?.to_string(),
        kind: j.req_str("kind")?.to_string(),
        cin: j.req_usize("cin")?,
        cout: j.req_usize("cout")?,
        kernel: j.req_usize("kernel")?,
        stride: j.req_usize("stride")?,
        in_spatial: j.req_usize("in_spatial")?,
        out_spatial: j.req_usize("out_spatial")?,
        prunable: j.req_bool("prunable")?,
        group: j.req_f64("group")? as i64,
        depthwise: j.req_bool("depthwise")?,
    })
}

/// Parse `meta_<variant>.json`.
pub fn load_meta(path: &Path) -> Result<ModelMeta> {
    let j = Json::read_file(path)?;
    let layers = j
        .req_arr("layers")?
        .iter()
        .map(layer)
        .collect::<Result<Vec<_>>>()?;
    let params = j
        .req_arr("params")?
        .iter()
        .map(entry)
        .collect::<Result<Vec<_>>>()?;
    let policy = j
        .req_arr("policy")?
        .iter()
        .map(entry)
        .collect::<Result<Vec<_>>>()?;
    let trainable = j
        .req_arr("trainable")?
        .iter()
        .map(|v| v.as_usize().context("trainable index"))
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelMeta {
        variant: j.req_str("variant")?.to_string(),
        img: j.req_usize("img")?,
        classes: j.req_usize("classes")?,
        width: j.req_usize("width")?,
        blocks: j
            .req_arr("blocks")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect(),
        eval_batch: j.req_usize("eval_batch")?,
        train_batch: j.req_usize("train_batch")?,
        base_test_acc: j.get("base_test_acc").and_then(Json::as_f64).unwrap_or(0.0),
        layers,
        params,
        policy,
        trainable,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "variant": "micro", "img": 32, "classes": 10, "width": 8,
      "blocks": [1,1,1,1], "eval_batch": 128, "train_batch": 64,
      "base_test_acc": 0.91,
      "layers": [
        {"name":"stem","kind":"conv","cin":3,"cout":8,"kernel":3,"stride":1,
         "in_spatial":32,"out_spatial":32,"prunable":false,"group":0,"depthwise":false},
        {"name":"fc","kind":"linear","cin":64,"cout":10,"kernel":1,"stride":1,
         "in_spatial":1,"out_spatial":1,"prunable":false,"group":-1,"depthwise":false}
      ],
      "params": [{"name":"stem.w","shape":[3,3,3,8],"trainable":true}],
      "policy": [{"name":"stem.mask","shape":[8]},{"name":"stem.w_bits","shape":[]}],
      "trainable": [0]
    }"#;

    #[test]
    fn parses_sample() {
        let p = std::env::temp_dir().join(format!("galen_meta_{}.json", std::process::id()));
        std::fs::write(&p, SAMPLE).unwrap();
        let m = load_meta(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.variant, "micro");
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].cout, 8);
        assert_eq!(m.layers[1].kind, "linear");
        assert_eq!(m.params[0].shape, vec![3, 3, 3, 8]);
        assert_eq!(m.policy[1].shape, Vec::<usize>::new());
        assert!((m.base_test_acc - 0.91).abs() < 1e-9);
    }
}
