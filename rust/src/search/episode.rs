//! Policy evaluation backends, search result records, and the
//! `run_search` convenience wrapper.
//!
//! The episode loop itself lives in [`crate::search::SearchDriver`]
//! (`driver.rs`); `run_search` is a thin run-to-completion wrapper over it,
//! kept for callers that want the original one-call API.

use anyhow::Result;

use crate::agent::PolicyMapper;
use crate::compress::{DiscretePolicy, QuantMode};
use crate::eval::SensitivityTable;
use crate::hw::LatencyProvider;
use crate::model::ModelIr;
use crate::search::{SearchBuilder, SearchConfig};
use crate::util::json::Json;

/// Accuracy provider, abstracted so the search runs against either the real
/// PJRT evaluator or the fast synthetic model (`SimEvaluator`) in tests and
/// simulator-only benches.
pub trait PolicyEvaluator {
    /// Validation accuracy of `policy` (or a deterministic proxy of it).
    fn accuracy(&self, policy: &DiscretePolicy) -> Result<f64>;
    /// Accuracy of the uncompressed model on the same split.
    fn base_accuracy(&self) -> f64;
}

impl PolicyEvaluator for (&crate::eval::Evaluator, crate::eval::Split, usize) {
    fn accuracy(&self, policy: &DiscretePolicy) -> Result<f64> {
        self.0.accuracy(policy, self.1, self.2)
    }
    fn base_accuracy(&self) -> f64 {
        self.0.reg.ir.base_test_acc
    }
}

/// Deterministic synthetic accuracy model: per-layer degradation terms with
/// depth-dependent sensitivity.  Mirrors the paper's qualitative structure
/// (later layers more sensitive to quantization, extreme bit widths
/// catastrophic, moderate pruning cheap) so agent dynamics are realistic
/// without a PJRT device — used by unit tests and the simulator-scale
/// benches.
pub struct SimEvaluator {
    /// Original output widths per layer (pruning-damage baseline).
    pub couts: Vec<usize>,
    /// Accuracy of the uncompressed model (damage baseline).
    pub base_acc: f64,
}

impl SimEvaluator {
    /// A synthetic evaluator calibrated to `ir`'s layer widths.
    pub fn new(ir: &ModelIr) -> Self {
        Self {
            couts: ir.layers.iter().map(|l| l.cout).collect(),
            base_acc: if ir.base_test_acc > 0.0 {
                ir.base_test_acc
            } else {
                0.93
            },
        }
    }

    fn quant_damage(bits: u32, sens: f64) -> f64 {
        let b = bits as f64;
        if b >= 32.0 {
            0.0
        } else {
            // smooth blow-up under 3 bits, mild above
            sens * (0.002 + 0.9 / (1.0 + (1.8f64).powf(2.0 * (b - 2.0))))
        }
    }
}

impl PolicyEvaluator for SimEvaluator {
    fn accuracy(&self, policy: &DiscretePolicy) -> Result<f64> {
        let n = policy.layers.len() as f64;
        let mut damage = 0.0;
        for (i, l) in policy.layers.iter().enumerate() {
            let depth = (i + 1) as f64 / n; // later layers more sensitive
            let sens = 0.25 + 0.75 * depth;
            let (wb, ab) = l.quant.bits();
            damage += Self::quant_damage(wb, sens) * 0.5;
            damage += Self::quant_damage(ab, sens * 1.3) * 0.5;
        }
        // pruning damage: superlinear in the removed-channel fraction
        for (i, l) in policy.layers.iter().enumerate() {
            let depth = (i + 1) as f64 / n;
            let sens = 0.2 + 0.6 * (1.0 - depth); // early layers hurt more when pruned
            let removed = 1.0 - l.kept_channels as f64 / self.couts[i] as f64;
            damage += sens * 0.35 * removed.powf(1.8);
        }
        Ok((self.base_acc - damage).clamp(0.05, 1.0))
    }
    fn base_accuracy(&self) -> f64 {
        self.base_acc
    }
}

/// One line of the search history.
#[derive(Clone, Debug)]
pub struct EpisodeSummary {
    /// Episode index (0-based).
    pub episode: usize,
    /// Absolute reward of the episode's policy (paper Eq. 6).
    pub reward: f64,
    /// Validation accuracy (or synthetic proxy) of the policy.
    pub accuracy: f64,
    /// Measured latency of the policy (seconds).
    pub latency_s: f64,
    /// Multiply-accumulate count under the policy.
    pub macs: u64,
    /// Bit operations (MACs x w_bits x a_bits) under the policy.
    pub bops: u64,
}

impl EpisodeSummary {
    /// JSON form (one entry of a result record's `history` array).
    ///
    /// `macs`/`bops` are written twice: as plain numbers for human and
    /// tooling consumption, and as hex twins (`macs_hex`/`bops_hex`) —
    /// u64s above 2^53 do not survive the f64 number path, and checkpoint
    /// resume must reproduce them bit-exactly.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("episode", Json::num(self.episode as f64)),
            ("reward", Json::num(self.reward)),
            ("accuracy", Json::num(self.accuracy)),
            ("latency_s", Json::num(self.latency_s)),
            ("macs", Json::num(self.macs as f64)),
            ("bops", Json::num(self.bops as f64)),
            ("macs_hex", Json::hex64(self.macs)),
            ("bops_hex", Json::hex64(self.bops)),
        ])
    }

    /// Rebuild a summary serialized by [`EpisodeSummary::to_json`]
    /// (checkpoint history entries); every field round-trips bit-exactly
    /// (the u64 counters decode from their hex twins).
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            episode: j.req_usize("episode")?,
            reward: j.req_f64("reward")?,
            accuracy: j.req_f64("accuracy")?,
            latency_s: j.req_f64("latency_s")?,
            macs: j.req_hex64("macs_hex")?,
            bops: j.req_hex64("bops_hex")?,
        })
    }
}

/// Result of a policy search.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// The policy of the best (highest-reward) episode.
    pub best_policy: DiscretePolicy,
    /// Summary line of the best episode.
    pub best: EpisodeSummary,
    /// One summary per episode, in order.
    pub history: Vec<EpisodeSummary>,
    /// Latency of the uncompressed reference policy (seconds).
    pub base_latency_s: f64,
    /// Accuracy of the uncompressed model on the evaluation split.
    pub base_accuracy: f64,
    /// Which latency backend scored the search (`sim`/`measured`/`hybrid`).
    pub latency_backend: String,
}

impl SearchOutcome {
    /// Best-episode latency as a fraction of the uncompressed reference.
    pub fn relative_latency(&self) -> f64 {
        self.best.latency_s / self.base_latency_s
    }

    /// JSON form (the `outcome` block of a result record).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("best", self.best.to_json()),
            ("base_latency_s", Json::num(self.base_latency_s)),
            ("base_accuracy", Json::num(self.base_accuracy)),
            ("relative_latency", Json::num(self.relative_latency())),
            ("latency_backend", Json::str(self.latency_backend.clone())),
            (
                "history",
                Json::Arr(self.history.iter().map(|h| h.to_json()).collect()),
            ),
        ])
    }
}

/// Run a full policy search (paper Fig. 1 outer loop) start to finish.
///
/// This is a thin wrapper over [`crate::search::SearchDriver`]: it builds
/// the driver from `cfg` and runs it to completion, so the result is
/// bit-identical to stepping the driver manually (asserted in
/// `tests/integration_driver.rs`).  Use the driver directly for
/// episode-granular control, the `SearchEvent` observer stream, or
/// checkpoint/resume.
///
/// `base` starts episodes from a fixed pre-compressed policy instead of the
/// reference — the sequential search schemes of the appendix fix one
/// method's parameters and search the other.
///
/// `latency` is the pluggable hardware backend: the analytical simulator,
/// the measured-kernel profiler, or the calibrated hybrid — the search loop
/// is agnostic to which one scores the policies.
pub fn run_search(
    ir: &ModelIr,
    sens: &SensitivityTable,
    evaluator: &dyn PolicyEvaluator,
    latency: &mut dyn LatencyProvider,
    mapper: &dyn PolicyMapper,
    cfg: &SearchConfig,
    base: Option<&DiscretePolicy>,
) -> Result<SearchOutcome> {
    let mut builder = SearchBuilder::from_config(cfg.clone());
    if let Some(p) = base {
        builder = builder.base_policy(p.clone());
    }
    builder
        .build(ir, sens, evaluator, latency, mapper)?
        .run_to_completion()
}

/// Count MIX/INT8/FP32 usage of a policy (analysis helper).
pub fn quant_histogram(policy: &DiscretePolicy) -> (usize, usize, usize) {
    let mut mix = 0;
    let mut int8 = 0;
    let mut fp32 = 0;
    for l in &policy.layers {
        match l.quant {
            QuantMode::Mix { .. } => mix += 1,
            QuantMode::Int8 => int8 += 1,
            QuantMode::Fp32 => fp32 += 1,
        }
    }
    (mix, int8, fp32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::{AgentKind, DdpgConfig, JointMapper, PruningMapper, QuantizationMapper};
    use crate::eval::SensitivityConfig;
    use crate::hw::{CostModel, HwTarget, LatencySimulator, MeasuredProfiler, ProfilerConfig};
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn setup() -> (ModelIr, SensitivityTable, LatencySimulator) {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sens =
            SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
        let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 11);
        (ir, sens, sim)
    }

    fn fast_cfg(agent: AgentKind, target: f64) -> SearchConfig {
        let mut cfg = SearchConfig::fast(agent, target);
        cfg.episodes = 40;
        cfg.warmup_episodes = 8;
        cfg.ddpg = DdpgConfig {
            hidden: (48, 32),
            batch: 32,
            replay_capacity: 600,
            ..Default::default()
        };
        cfg.log_every = 0;
        cfg
    }

    #[test]
    fn quant_search_approaches_target() {
        let (ir, sens, mut sim) = setup();
        let ev = SimEvaluator::new(&ir);
        let mapper = QuantizationMapper::default();
        let cfg = fast_cfg(AgentKind::Quantization, 0.5);
        let out = run_search(&ir, &sens, &ev, &mut sim, &mapper, &cfg, None).unwrap();
        assert_eq!(out.history.len(), 40);
        // tiny model never supports MIX (cin < 32): INT8-everywhere is the
        // compression floor, so just require genuine compression + INT8 use
        assert!(
            out.relative_latency() < 0.95,
            "rel latency {}",
            out.relative_latency()
        );
        let (_, int8, _) = quant_histogram(&out.best_policy);
        assert!(int8 >= ir.layers.len() / 2, "expected INT8 adoption");
        assert!(out.best.accuracy > 0.5);
        // reward history: best is the max
        let max = out
            .history
            .iter()
            .map(|h| h.reward)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((max - out.best.reward).abs() < 1e-12);
    }

    #[test]
    fn pruning_search_prunes_only_prunable() {
        let (ir, sens, mut sim) = setup();
        let ev = SimEvaluator::new(&ir);
        let mapper = PruningMapper::default();
        let cfg = fast_cfg(AgentKind::Pruning, 0.6);
        let out = run_search(&ir, &sens, &ev, &mut sim, &mapper, &cfg, None).unwrap();
        for l in &ir.layers {
            let kept = out.best_policy.layers[l.index].kept_channels;
            if !l.prunable {
                assert_eq!(kept, l.cout, "{} must stay unpruned", l.name);
            }
            assert_eq!(out.best_policy.layers[l.index].quant, QuantMode::Fp32);
        }
        // macs must shrink
        assert!(out.best.macs < ir.total_macs());
    }

    #[test]
    fn joint_search_uses_both_methods() {
        let (ir, sens, mut sim) = setup();
        let ev = SimEvaluator::new(&ir);
        let mapper = JointMapper::default();
        let cfg = fast_cfg(AgentKind::Joint, 0.4);
        let out = run_search(&ir, &sens, &ev, &mut sim, &mapper, &cfg, None).unwrap();
        let (_mix, int8, fp32) = quant_histogram(&out.best_policy);
        assert!(int8 + fp32 == ir.layers.len());
        assert!(out.best.bops < ir.total_macs() * 32 * 32);
    }

    #[test]
    fn base_policy_is_respected() {
        let (ir, sens, mut sim) = setup();
        let ev = SimEvaluator::new(&ir);
        // fix pruning, search quantization on top
        let mut base = DiscretePolicy::reference(&ir);
        base.layers[1].kept_channels = 2;
        let mapper = QuantizationMapper::default();
        let cfg = fast_cfg(AgentKind::Quantization, 0.4);
        let out = run_search(&ir, &sens, &ev, &mut sim, &mapper, &cfg, Some(&base)).unwrap();
        assert_eq!(
            out.best_policy.layers[1].kept_channels, 2,
            "pruning from the base policy must survive the quantization run"
        );
    }

    #[test]
    fn search_runs_with_measured_profiler_backend() {
        // The acceptance path: the episode loop is backend-agnostic, so a
        // MeasuredProfiler (real kernel timings) drops in for the simulator.
        let (ir, sens, _) = setup();
        let ev = SimEvaluator::new(&ir);
        let mapper = QuantizationMapper::default();
        let mut cfg = fast_cfg(AgentKind::Quantization, 0.5);
        cfg.episodes = 6;
        cfg.warmup_episodes = 2;
        let mut profiler =
            MeasuredProfiler::new(HwTarget::cortex_a72(), "tiny", ProfilerConfig::fast());
        let out = run_search(&ir, &sens, &ev, &mut profiler, &mapper, &cfg, None).unwrap();
        assert_eq!(out.history.len(), 6);
        assert_eq!(out.latency_backend, "measured");
        assert!(out.best.latency_s > 0.0);
        assert!(out.base_latency_s > 0.0);
        let stats = profiler.stats();
        assert!(stats.measured > 0, "the profiler must have timed kernels");
        assert!(
            stats.hits > 0,
            "repeat configurations must be served from the cache"
        );
    }

    #[test]
    fn episode_summary_json_roundtrip_is_exact() {
        let s = EpisodeSummary {
            episode: 41,
            reward: 0.8612345678901234,
            accuracy: 0.912345,
            latency_s: 0.00123456789,
            macs: 123_456_789,
            bops: (1u64 << 53) + 1, // not representable in f64: needs the hex twin
        };
        let back =
            EpisodeSummary::from_json(&Json::parse(&s.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.episode, s.episode);
        assert_eq!(back.reward.to_bits(), s.reward.to_bits());
        assert_eq!(back.accuracy.to_bits(), s.accuracy.to_bits());
        assert_eq!(back.latency_s.to_bits(), s.latency_s.to_bits());
        assert_eq!(back.macs, s.macs);
        assert_eq!(back.bops, s.bops);
    }

    #[test]
    fn deterministic_given_seed() {
        let (ir, sens, _) = setup();
        let ev = SimEvaluator::new(&ir);
        let mapper = QuantizationMapper::default();
        let mut cfg = fast_cfg(AgentKind::Quantization, 0.5);
        cfg.episodes = 12;
        let mut sim1 = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
        let mut sim2 = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 5);
        let a = run_search(&ir, &sens, &ev, &mut sim1, &mapper, &cfg, None).unwrap();
        let b = run_search(&ir, &sens, &ev, &mut sim2, &mapper, &cfg, None).unwrap();
        assert_eq!(a.best.reward, b.best.reward);
        assert_eq!(a.best_policy, b.best_policy);
    }
}
