//! Parallel Pareto-sweep orchestrator.
//!
//! The paper's headline result is a *sweep*: one policy search per
//! `(agent kind, latency target)` cell, repeated until the trade-off curve
//! between accuracy and relative latency is mapped out.  Every cell is an
//! independent `run_search` call, so the sweep is embarrassingly parallel —
//! this module fans a [`SweepGrid`] of jobs out across a work queue of
//! `GALEN_NUM_THREADS` workers (`util::parallel_map`) and folds the
//! outcomes into a dominance-filtered [`ParetoFront`].
//!
//! Three properties make the fan-out safe and reproducible:
//!
//! * **Deterministic per-job seeding** — each job's RNG seed is a pure
//!   function of `(base seed, agent, target, replicate)`
//!   ([`job_seed`]), never of worker identity or scheduling order, so an
//!   N-worker sweep is result-identical to the 1-worker sweep
//!   (`tests/integration_sweep.rs` asserts bit-equality).
//! * **Shared latency caches** — every worker's `LatencyProvider` hangs off
//!   one [`LatencyFactory`], whose `hw::SharedCostCache` /
//!   `hw::SharedProfileCache` let concurrent searches reuse each other's
//!   per-layer costs and kernel measurements instead of re-deriving them.
//! * **Accuracy proxy** — jobs score accuracy with the deterministic
//!   `SimEvaluator` (the PJRT evaluator is not thread-safe), which is
//!   exactly the trade-off the front records: accuracy-*proxy* versus
//!   relative latency.  Validate the chosen front points afterwards with
//!   `galen validate` / `Session::search`.
//!
//! Artifacts land in `sweeps/<target>/<model>.json` (schema-versioned,
//! see [`ParetoFront::save`]), next to the PR 2 profile caches.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::agent::{mapper_for, AgentKind};
use crate::compress::DiscretePolicy;
use crate::eval::SensitivityTable;
use crate::hw::{
    CostModel, HwTarget, HybridProvider, LatencyKind, LatencyProvider, LatencySimulator,
    MeasuredProfiler, ProfilerConfig, SharedCostCache, SharedProfileCache,
};
use crate::model::ModelIr;
use crate::obs;
use crate::search::{run_search, SearchConfig, SearchOutcome, SimEvaluator};
use crate::testing::FaultPlan;
use crate::util::json::Json;
use crate::util::sync::lock;
use crate::util::{num_threads, parallel_map, Fnv1a};

// Registry handles for the sweep's process-wide series.  Resolved lazily
// (one registry lookup, ever) so the fan-out hot path touches nothing but
// the shared atomic cells.
fn obs_jobs_completed() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("sweep_jobs_completed_total", &[]))
}

fn obs_jobs_stolen() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("sweep_jobs_stolen_total", &[]))
}

/// Version of the on-disk sweep-artifact layout; mismatched artifacts are
/// rejected by [`ParetoFront::from_json`], never mis-parsed.
pub const SWEEP_SCHEMA_VERSION: usize = 1;

/// One cell of a sweep: a full policy search for `agent` towards latency
/// target `target`, seeded with `seed`.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepJob {
    /// Which agent runs the search (pruning / quantization / joint).
    pub agent: AgentKind,
    /// Target compression rate c (fraction of the reference latency).
    pub target: f64,
    /// The job's search seed (pure function of the job description).
    pub seed: u64,
}

/// The sweep grid: `agents x targets x replicates` jobs.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// Agent kinds to sweep (one curve per kind, as in the paper's Fig. 4).
    pub agents: Vec<AgentKind>,
    /// Latency targets c to sweep.
    pub targets: Vec<f64>,
    /// Independent seeds per `(agent, target)` cell (>= 1).
    pub replicates: usize,
}

impl SweepGrid {
    /// A grid of one job per `(agent, target)` pair.
    pub fn new(agents: Vec<AgentKind>, targets: Vec<f64>) -> Self {
        Self {
            agents,
            targets,
            replicates: 1,
        }
    }

    /// Run `n` independently seeded searches per cell (Pareto fronts
    /// benefit from restarts; dominated replicates are filtered anyway).
    pub fn with_replicates(mut self, n: usize) -> Self {
        self.replicates = n.max(1);
        self
    }

    /// Number of jobs in the grid.
    pub fn len(&self) -> usize {
        self.agents.len() * self.targets.len() * self.replicates.max(1)
    }

    /// Whether the grid has no jobs.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the job list.  Job seeds derive from `base_seed` via
    /// [`job_seed`], so the list — and therefore the whole sweep — is
    /// independent of worker count and scheduling order.
    pub fn jobs(&self, base_seed: u64) -> Vec<SweepJob> {
        let mut out = Vec::with_capacity(self.len());
        for &agent in &self.agents {
            for &target in &self.targets {
                for r in 0..self.replicates.max(1) {
                    out.push(SweepJob {
                        agent,
                        target,
                        seed: job_seed(base_seed, agent, target, r),
                    });
                }
            }
        }
        out
    }
}

/// Deterministic seed of one sweep job: a pure function of the job
/// description (never of its position in the queue or the worker that
/// runs it) — the cornerstone of worker-count-invariant sweeps.
pub fn job_seed(base_seed: u64, agent: AgentKind, target: f64, replicate: usize) -> u64 {
    let mut h = Fnv1a::seeded(base_seed ^ 0x9a1e_5eed_0b5e_55ed);
    h.mix_bytes(agent.to_string().as_bytes());
    h.mix(target.to_bits());
    h.mix(replicate as u64);
    h.finish()
}

/// Builds one `LatencyProvider` per sweep job, all sharing the same
/// cross-worker caches (`hw::SharedCostCache` / `hw::SharedProfileCache`).
///
/// Cheap to construct from a `coordinator::Session`
/// (`Session::latency_factory`); construct directly for harnesses that have
/// no session (benches, tests).
#[derive(Clone, Debug)]
pub struct LatencyFactory {
    kind: LatencyKind,
    target: HwTarget,
    variant: String,
    profiler_cfg: ProfilerConfig,
    profiles_dir: Option<PathBuf>,
    cost_cache: SharedCostCache,
    profile_cache: SharedProfileCache,
    faults: FaultPlan,
}

impl LatencyFactory {
    /// A factory producing `kind` providers for `target`/`variant`, with
    /// fresh (empty) shared caches.  `profiles_dir` is the on-disk profile
    /// cache root for measured/hybrid providers (None keeps measurements in
    /// memory only).
    pub fn new(
        kind: LatencyKind,
        target: HwTarget,
        variant: &str,
        profiler_cfg: ProfilerConfig,
        profiles_dir: Option<PathBuf>,
    ) -> Self {
        Self {
            kind,
            target,
            variant: variant.to_string(),
            profiler_cfg,
            profiles_dir,
            cost_cache: SharedCostCache::new(),
            profile_cache: SharedProfileCache::new(),
            faults: FaultPlan::none(),
        }
    }

    /// Arm a fault-injection plan on every measured/hybrid provider this
    /// factory builds (`measure` / `profile-write` sites).  Clones of the
    /// plan share hit counters, so "fail the 3rd measurement of the run"
    /// means the 3rd across all providers.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Which latency backend this factory produces.
    pub fn kind(&self) -> LatencyKind {
        self.kind
    }

    fn simulator(&self, seed: u64) -> LatencySimulator {
        LatencySimulator::new(CostModel::new(self.target.clone()), seed)
            .with_shared_cache(self.cost_cache.clone())
    }

    fn profiler(&self) -> Result<MeasuredProfiler> {
        let p = match &self.profiles_dir {
            Some(dir) => MeasuredProfiler::with_cache(
                self.target.clone(),
                &self.variant,
                self.profiler_cfg.clone(),
                dir,
            )?,
            None => MeasuredProfiler::new(
                self.target.clone(),
                &self.variant,
                self.profiler_cfg.clone(),
            ),
        };
        Ok(p.with_shared_cache(self.profile_cache.clone())
            .with_faults(self.faults.clone()))
    }

    /// One latency provider for one job, wired to the shared caches.
    /// Hybrid providers are calibrated against the default probe set (whose
    /// measurements are themselves shared across workers).
    pub fn provider(&self, seed: u64, ir: &ModelIr) -> Result<Box<dyn LatencyProvider>> {
        match self.kind {
            LatencyKind::Sim => Ok(Box::new(self.simulator(seed))),
            LatencyKind::Measured => Ok(Box::new(self.profiler()?)),
            LatencyKind::Hybrid => {
                let mut hybrid = HybridProvider::new(self.profiler()?, self.simulator(seed));
                hybrid.calibrate_default(ir);
                Ok(Box::new(hybrid))
            }
        }
    }

    /// Write the sweep's pooled measurements to the on-disk profile cache,
    /// once, after the fan-out barrier (so concurrent workers never race on
    /// the manifest file).  No-op for the simulator backend or when the
    /// factory has no profiles directory.
    pub fn persist(&self) -> Result<Option<PathBuf>> {
        if self.kind == LatencyKind::Sim || self.profiles_dir.is_none() {
            return Ok(None);
        }
        let mut p = self.profiler()?;
        p.absorb_shared();
        p.save()
    }
}

/// One finished sweep job.
#[derive(Debug)]
pub struct SweepOutcome {
    /// The job that produced this outcome.
    pub job: SweepJob,
    /// The search result (best policy, history, backend label).
    pub outcome: SearchOutcome,
    /// Wall-clock seconds this job took on its worker.
    pub wall_s: f64,
}

/// Everything a sweep produced: per-job outcomes plus the Pareto front.
#[derive(Debug)]
pub struct SweepReport {
    /// Per-job outcomes, in deterministic grid order.
    pub outcomes: Vec<SweepOutcome>,
    /// The dominance-filtered accuracy-proxy / relative-latency front.
    pub front: ParetoFront,
    /// Worker threads the sweep actually used.
    pub workers: usize,
    /// End-to-end wall-clock seconds of the fan-out.
    pub wall_s: f64,
}

impl SweepReport {
    /// Per-job summary table (one row per job, grid order).
    pub fn job_table(&self) -> String {
        let mut s = format!(
            "{:16} {:>5} {:>10} {:>10} {:>9} {:>8}\n",
            "agent", "c", "rel.lat", "accuracy", "reward", "wall"
        );
        for o in &self.outcomes {
            s.push_str(&format!(
                "{:16} {:>5.2} {:>9.1}% {:>9.2}% {:>9.3} {:>7.1}s\n",
                o.job.agent,
                o.job.target,
                o.outcome.relative_latency() * 100.0,
                o.outcome.best.accuracy * 100.0,
                o.outcome.best.reward,
                o.wall_s,
            ));
        }
        s
    }
}

/// Run a sweep: fan `grid`'s jobs across `workers` threads (0 = all cores,
/// see `util::num_threads`), each job a full `run_search` with `proto`'s
/// hyper-parameters, the factory's latency backend, and the synthetic
/// accuracy proxy.  Returns per-job outcomes plus the Pareto front; pooled
/// measurements are persisted once after the barrier.
///
/// With the simulator backend the result is bit-identical for every
/// `workers` value: job seeds are pure functions of the grid, jobs do not
/// interact, and every shared-cache value is a pure function of its
/// configuration.  The measured/hybrid backends are consistent *within*
/// one sweep (canonical-first sharing) but carry run-to-run timing
/// jitter, so bit-identity across separate runs only holds for `sim`.
pub fn run_sweep(
    ir: &ModelIr,
    sens: &SensitivityTable,
    grid: &SweepGrid,
    proto: &SearchConfig,
    workers: usize,
    factory: &LatencyFactory,
) -> Result<SweepReport> {
    let jobs = grid.jobs(proto.seed);
    anyhow::ensure!(!jobs.is_empty(), "sweep grid has no (agent, target) jobs");
    let workers = if workers == 0 { num_threads() } else { workers };
    let workers = workers.min(jobs.len());
    log::info!(
        "sweep: {} jobs on {} workers ({} backend)",
        jobs.len(),
        workers,
        factory.kind()
    );
    let t0 = Instant::now();
    // (job index, executing thread, busy seconds) per job, folded into
    // per-worker utilization gauges after the barrier — never on the hot
    // path, and never into the results (worker identity must not leak
    // into outcomes, or N-worker bit-identity would break).
    let timings: Mutex<Vec<(usize, usize, f64)>> = Mutex::new(Vec::new());
    let indexed: Vec<(usize, SweepJob)> = jobs.into_iter().enumerate().collect();
    let results = parallel_map(indexed, workers, |(idx, job)| {
        let _sp = obs::trace::span("sweep_job")
            .arg("agent", job.agent.to_string())
            .arg("target", format!("{}", job.target));
        let tid = obs::metrics::thread_id();
        let jt0 = Instant::now();
        let out = run_job(ir, sens, proto, job, factory);
        lock(&timings).push((idx, tid, jt0.elapsed().as_secs_f64()));
        obs_jobs_completed().inc();
        out
    });
    let mut outcomes = Vec::with_capacity(results.len());
    for r in results {
        outcomes.push(r?);
    }
    record_worker_metrics(&lock(&timings), workers, t0.elapsed().as_secs_f64());
    if let Some(path) = factory.persist()? {
        log::info!("sweep: pooled profile cache written to {}", path.display());
    }
    let front = ParetoFront::from_outcomes(&outcomes);
    Ok(SweepReport {
        outcomes,
        front,
        workers,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Post-barrier worker metrics: per-worker utilization gauges
/// (`sweep_worker_utilization{worker="<slot>"}`, busy seconds over sweep
/// wall seconds) plus the stolen-jobs counter.  A job counts as *stolen*
/// when the work queue let a worker other than its round-robin owner
/// (slot `index % workers`) execute it — the signature of the imbalance
/// the shared queue exists to absorb.  Worker slots are assigned by
/// sorting the distinct executing thread ids, so the labels are stable
/// within a process regardless of spawn order.
fn record_worker_metrics(timings: &[(usize, usize, f64)], workers: usize, wall_s: f64) {
    if timings.is_empty() {
        return;
    }
    let mut tids: Vec<usize> = timings.iter().map(|&(_, tid, _)| tid).collect();
    tids.sort_unstable();
    tids.dedup();
    let slot_of = |tid: usize| tids.iter().position(|&t| t == tid).unwrap_or(0);
    let mut busy = vec![0.0f64; tids.len()];
    let mut stolen = 0u64;
    for &(idx, tid, s) in timings {
        let slot = slot_of(tid);
        busy[slot] += s;
        if slot != idx % workers {
            stolen += 1;
        }
    }
    if stolen > 0 {
        obs_jobs_stolen().add(stolen);
    }
    for (slot, &b) in busy.iter().enumerate() {
        let worker = slot.to_string();
        obs::Gauge::register("sweep_worker_utilization", &[("worker", &worker)])
            .set(if wall_s > 0.0 { b / wall_s } else { 0.0 });
    }
}

/// One worker's job: a full search with the job's agent/target/seed.
fn run_job(
    ir: &ModelIr,
    sens: &SensitivityTable,
    proto: &SearchConfig,
    job: SweepJob,
    factory: &LatencyFactory,
) -> Result<SweepOutcome> {
    let mut cfg = proto.clone();
    cfg.agent = job.agent;
    cfg.target = job.target;
    cfg.seed = job.seed;
    let mapper = mapper_for(cfg.agent);
    let ev = SimEvaluator::new(ir);
    // same seed split as Session::search, so a 1-worker sweep reproduces
    // the sequential per-cell searches exactly
    let mut provider = factory.provider(cfg.seed ^ 0x5117, ir)?;
    let t0 = Instant::now();
    let outcome = run_search(ir, sens, &ev, provider.as_mut(), mapper.as_ref(), &cfg, None)?;
    Ok(SweepOutcome {
        job,
        outcome,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// One candidate point of the trade-off curve: a discretized policy with
/// its accuracy proxy and latency relative to the uncompressed reference.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoPoint {
    /// Agent kind label that found the policy.
    pub agent: String,
    /// The latency target c the search aimed for.
    pub target: f64,
    /// The search seed (for exact replay).
    pub seed: u64,
    /// Accuracy proxy of the best policy.
    pub accuracy: f64,
    /// Absolute latency (seconds) under the sweep's latency backend.
    pub latency_s: f64,
    /// Latency as a fraction of the uncompressed reference.
    pub relative_latency: f64,
    /// The search's reward for the best episode.
    pub reward: f64,
    /// The discretized compression policy itself.
    pub policy: DiscretePolicy,
}

impl ParetoPoint {
    /// Build a point from one finished sweep job.
    pub fn from_outcome(o: &SweepOutcome) -> Self {
        Self {
            agent: o.job.agent.to_string(),
            target: o.job.target,
            seed: o.job.seed,
            accuracy: o.outcome.best.accuracy,
            latency_s: o.outcome.best.latency_s,
            relative_latency: o.outcome.relative_latency(),
            reward: o.outcome.best.reward,
            policy: o.outcome.best_policy.clone(),
        }
    }

    /// Strict Pareto dominance: at least as good on both axes (higher
    /// accuracy, lower relative latency) and strictly better on one.
    pub fn dominates(&self, other: &Self) -> bool {
        self.accuracy >= other.accuracy
            && self.relative_latency <= other.relative_latency
            && (self.accuracy > other.accuracy || self.relative_latency < other.relative_latency)
    }

    /// Hash of the discretized policy — the dedup key: two jobs that land
    /// on the same policy contribute one point.
    pub fn policy_key(&self) -> u64 {
        let mut h = Fnv1a::new();
        for l in &self.policy.layers {
            h.mix(l.kept_channels as u64);
            h.mix(l.quant.class_id());
            let (wb, ab) = l.quant.bits();
            h.mix(((wb as u64) << 32) | ab as u64);
        }
        h.finish()
    }

    /// JSON form (one entry of the sweep artifact's `points` array).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("agent", Json::str(self.agent.clone())),
            ("target", Json::num(self.target)),
            // hex string: u64 seeds do not survive the f64 number path
            ("seed", Json::hex64(self.seed)),
            ("accuracy", Json::num(self.accuracy)),
            ("latency_s", Json::num(self.latency_s)),
            ("relative_latency", Json::num(self.relative_latency)),
            ("reward", Json::num(self.reward)),
            ("policy", self.policy.to_json()),
        ])
    }

    /// Parse one artifact point back (inverse of `to_json`).
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            agent: j.req_str("agent")?.to_string(),
            target: j.req_f64("target")?,
            seed: j.req_hex64("seed")?,
            accuracy: j.req_f64("accuracy")?,
            latency_s: j.req_f64("latency_s")?,
            relative_latency: j.req_f64("relative_latency")?,
            reward: j.req_f64("reward")?,
            policy: DiscretePolicy::from_json(j.req("policy")?)?,
        })
    }
}

/// The dominance-filtered, policy-deduplicated accuracy-proxy vs.
/// relative-latency front of a sweep, sorted by relative latency.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoFront {
    /// Non-dominated points, ascending relative latency.
    pub points: Vec<ParetoPoint>,
}

impl ParetoFront {
    /// Build the front from finished sweep jobs (dedup then dominance
    /// filter, see `from_points`).
    pub fn from_outcomes(outs: &[SweepOutcome]) -> Self {
        Self::from_points(outs.iter().map(ParetoPoint::from_outcome).collect())
    }

    /// Build the front from raw candidate points: duplicate policies keep
    /// their first occurrence, dominated points are dropped, survivors are
    /// sorted by (relative latency asc, accuracy desc, agent, target) —
    /// a total order, so equal inputs give byte-equal fronts.
    pub fn from_points(candidates: Vec<ParetoPoint>) -> Self {
        let mut seen = std::collections::HashSet::new();
        let candidates: Vec<ParetoPoint> = candidates
            .into_iter()
            .filter(|p| seen.insert(p.policy_key()))
            .collect();
        let mut points: Vec<ParetoPoint> = candidates
            .iter()
            .filter(|p| !candidates.iter().any(|q| q.dominates(p)))
            .cloned()
            .collect();
        points.sort_by(|a, b| {
            a.relative_latency
                .total_cmp(&b.relative_latency)
                .then(b.accuracy.total_cmp(&a.accuracy))
                .then(a.agent.cmp(&b.agent))
                .then(a.target.total_cmp(&b.target))
        });
        Self { points }
    }

    /// The versioned artifact form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(SWEEP_SCHEMA_VERSION as f64)),
            (
                "points",
                Json::Arr(self.points.iter().map(|p| p.to_json()).collect()),
            ),
        ])
    }

    /// Parse an artifact (inverse of `to_json`); rejects unknown schema
    /// versions.
    pub fn from_json(j: &Json) -> Result<Self> {
        anyhow::ensure!(
            j.req_usize("schema_version")? == SWEEP_SCHEMA_VERSION,
            "sweep artifact schema version mismatch"
        );
        let mut points = Vec::new();
        for e in j.req_arr("points")? {
            points.push(ParetoPoint::from_json(e)?);
        }
        Ok(Self { points })
    }

    /// Write the artifact to `dir/<target>/<model>.json` (the same
    /// `<target>` directory naming as the profile caches).  Returns the
    /// path written.
    pub fn save(&self, dir: &Path, target: &str, model: &str) -> Result<PathBuf> {
        let path = dir
            .join(crate::hw::sanitize(target))
            .join(format!("{model}.json"));
        self.to_json().write_file(&path)?;
        Ok(path)
    }

    /// Load an artifact written by `save`.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::read_file(path)?)
    }

    /// Human-readable front (one row per point, ascending latency).
    pub fn table(&self) -> String {
        let mut s = format!(
            "{:16} {:>5} {:>10} {:>10} {:>9}\n",
            "agent", "c", "rel.lat", "accuracy", "reward"
        );
        for p in &self.points {
            s.push_str(&format!(
                "{:16} {:>5.2} {:>9.1}% {:>9.2}% {:>9.3}\n",
                p.agent,
                p.target,
                p.relative_latency * 100.0,
                p.accuracy * 100.0,
                p.reward,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{LayerCmp, QuantMode};
    use crate::eval::SensitivityConfig;
    use crate::model::ir::test_fixtures::tiny_meta;

    fn pt(agent: &str, acc: f64, rel: f64, channels: usize) -> ParetoPoint {
        ParetoPoint {
            agent: agent.to_string(),
            target: rel,
            seed: 7,
            accuracy: acc,
            latency_s: rel,
            relative_latency: rel,
            reward: acc - rel,
            policy: DiscretePolicy {
                layers: vec![LayerCmp {
                    kept_channels: channels,
                    quant: QuantMode::Int8,
                }],
            },
        }
    }

    #[test]
    fn dominance_filtering_keeps_only_the_front() {
        // (acc, rel): (0.9, 0.5) dominates (0.8, 0.6); (0.95, 0.9) survives
        // on accuracy, (0.7, 0.3) survives on latency.
        let front = ParetoFront::from_points(vec![
            pt("a", 0.9, 0.5, 1),
            pt("b", 0.8, 0.6, 2),
            pt("c", 0.95, 0.9, 3),
            pt("d", 0.7, 0.3, 4),
        ]);
        let survivors: Vec<&str> = front.points.iter().map(|p| p.agent.as_str()).collect();
        assert_eq!(survivors, vec!["d", "a", "c"], "sorted by relative latency");
        assert!(front.points.iter().all(|p| p.agent != "b"));
    }

    #[test]
    fn equal_points_with_distinct_policies_both_survive() {
        let a = pt("a", 0.9, 0.5, 1);
        let b = pt("b", 0.9, 0.5, 2); // same (acc, rel), different policy
        assert!(!a.dominates(&b) && !b.dominates(&a));
        let front = ParetoFront::from_points(vec![a, b]);
        assert_eq!(front.points.len(), 2);
    }

    #[test]
    fn duplicate_policies_deduplicate_to_first_occurrence() {
        let first = pt("a", 0.9, 0.5, 1);
        let dup = ParetoPoint {
            agent: "b".to_string(),
            seed: 99,
            ..pt("b", 0.9, 0.5, 1)
        };
        assert_eq!(first.policy_key(), dup.policy_key());
        let front = ParetoFront::from_points(vec![first, dup]);
        assert_eq!(front.points.len(), 1);
        assert_eq!(front.points[0].agent, "a", "first occurrence wins");
    }

    #[test]
    fn policy_key_separates_modes_and_widths() {
        let base = pt("a", 0.9, 0.5, 4);
        let mut pruned = base.clone();
        pruned.policy.layers[0].kept_channels = 3;
        assert_ne!(base.policy_key(), pruned.policy_key());
        let mut mix88 = base.clone();
        mix88.policy.layers[0].quant = QuantMode::Mix { w_bits: 8, a_bits: 8 };
        assert_ne!(
            base.policy_key(),
            mix88.policy_key(),
            "MIX(8/8) must not collide with INT8"
        );
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut p = pt("joint", 0.912345678901234, 0.3333333333333333, 2);
        p.seed = 0xdead_beef_cafe_f00d; // > 2^53: must survive via hex
        p.policy.layers.push(LayerCmp {
            kept_channels: 5,
            quant: QuantMode::Mix { w_bits: 3, a_bits: 5 },
        });
        p.policy.layers.push(LayerCmp {
            kept_channels: 6,
            quant: QuantMode::Fp32,
        });
        let front = ParetoFront::from_points(vec![p]);
        let text = front.to_json().pretty(0);
        let back = ParetoFront::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, front);
    }

    #[test]
    fn from_json_rejects_schema_mismatch() {
        let j = Json::parse(r#"{"schema_version": 999, "points": []}"#).unwrap();
        assert!(ParetoFront::from_json(&j).is_err());
    }

    #[test]
    fn grid_jobs_are_deterministic_and_distinct() {
        let grid = SweepGrid::new(
            vec![AgentKind::Pruning, AgentKind::Joint],
            vec![0.3, 0.5],
        )
        .with_replicates(2);
        assert_eq!(grid.len(), 8);
        let a = grid.jobs(7);
        let b = grid.jobs(7);
        assert_eq!(a, b, "job list is a pure function of grid and base seed");
        let seeds: std::collections::HashSet<u64> = a.iter().map(|j| j.seed).collect();
        assert_eq!(seeds.len(), 8, "every cell gets a distinct seed");
        assert_ne!(grid.jobs(8)[0].seed, a[0].seed, "base seed feeds through");
    }

    #[test]
    fn two_worker_sweep_matches_one_worker_sweep() {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sens =
            SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
        let mut proto = SearchConfig::fast(AgentKind::Joint, 0.5);
        proto.episodes = 6;
        proto.warmup_episodes = 2;
        proto.opt_steps_per_episode = 4;
        proto.log_every = 0;
        let grid = SweepGrid::new(
            vec![AgentKind::Quantization, AgentKind::Joint],
            vec![0.4, 0.6],
        );
        let factory = |_: ()| {
            LatencyFactory::new(
                LatencyKind::Sim,
                HwTarget::cortex_a72(),
                "tiny",
                ProfilerConfig::fast(),
                None,
            )
        };
        let seq = run_sweep(&ir, &sens, &grid, &proto, 1, &factory(())).unwrap();
        let par = run_sweep(&ir, &sens, &grid, &proto, 2, &factory(())).unwrap();
        assert_eq!(seq.outcomes.len(), 4);
        assert_eq!(seq.front, par.front, "front must be worker-count invariant");
        for (a, b) in seq.outcomes.iter().zip(&par.outcomes) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.outcome.best_policy, b.outcome.best_policy);
            assert_eq!(a.outcome.best.reward, b.outcome.best.reward);
        }
        assert!(!seq.front.points.is_empty());
    }
}
