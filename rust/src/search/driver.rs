//! The resumable search state machine.
//!
//! [`SearchDriver`] owns one policy search (paper Fig. 1 outer loop) as an
//! explicit state machine instead of a blocking free function:
//!
//! * **granularity** — [`SearchDriver::step`] advances one layer decision,
//!   [`SearchDriver::run_episode`] one full episode,
//!   [`SearchDriver::run_to_completion`] the whole search; all three
//!   interleave freely and produce bit-identical trajectories (the step
//!   loop draws from exactly the same RNG streams in the same order).
//! * **observability** — [`SearchObserver`]s registered with
//!   [`SearchDriver::add_observer`] receive the [`SearchEvent`] stream
//!   (search started / episode finished / best improved / finished), which
//!   is what the `galen serve` job service multiplexes to clients.
//! * **checkpoint/resume** — [`SearchDriver::save_checkpoint`] serializes
//!   the complete search state (agent networks + optimizers + replay +
//!   normalizers + RNG streams, history, best policy) into a
//!   schema-versioned JSON document; [`SearchDriver::resume_from`] rebuilds
//!   a driver that continues the search **bit-identically** to an
//!   uninterrupted run (asserted in `tests/integration_driver.rs`).
//!
//! Construction goes through the typed [`SearchBuilder`] — the replacement
//! for threading stringly-typed JSON knobs into the search.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::agent::{Ddpg, PolicyMapper, StateBuilder, Transition};
use crate::compress::DiscretePolicy;
use crate::eval::SensitivityTable;
use crate::hw::LatencyProvider;
use crate::model::ModelIr;
use crate::obs;
use crate::reward::{RewardModel, RewardSpec};
use crate::search::{EpisodeSummary, PolicyEvaluator, SearchConfig, SearchOutcome};
use crate::util::json::Json;

/// Version of the checkpoint document layout; mismatched checkpoints are
/// rejected by [`SearchDriver::resume_from`], never mis-parsed.
///
/// v2: the agent state vector gained a depthwise-flag feature (dim 13 ->
/// 14), so v1 checkpoints carry agent networks of the wrong input width —
/// the version bump rejects them with a clear schema error instead of the
/// generic dimension mismatch.
pub const CHECKPOINT_SCHEMA_VERSION: usize = 2;

/// The `kind` tag every checkpoint document carries.
const CHECKPOINT_KIND: &str = "galen_search_checkpoint";

/// One notification from a running search.  Emitted synchronously by the
/// driver; observers must not block (the search waits on them).
#[derive(Clone, Debug)]
pub enum SearchEvent {
    /// The first step of the (possibly resumed) search is about to run.
    Started {
        /// First episode this driver will run (> 0 after a resume).
        first_episode: usize,
        /// Total episodes of the search.
        episodes: usize,
        /// Reference (uncompressed) latency in seconds.
        base_latency_s: f64,
        /// Reference (uncompressed) accuracy.
        base_accuracy: f64,
        /// Label of the latency backend scoring the search.
        backend: String,
    },
    /// An episode was validated and folded into the agent.
    EpisodeFinished(EpisodeSummary),
    /// The episode's policy beat every previous episode's reward.
    BestImproved(EpisodeSummary),
    /// The final episode finished; `outcome()` is available.
    Finished {
        /// Episodes the search ran in total.
        episodes: usize,
        /// Reward of the best episode.
        best_reward: f64,
        /// Latency-backend cache hits over the whole search.
        cache_hits: u64,
        /// Latency-backend cache misses (or measurements) over the search.
        cache_misses: u64,
    },
}

impl SearchEvent {
    /// Serialize the event (the `galen serve` event-stream format): a
    /// `type` discriminant plus the event's fields.
    pub fn to_json(&self) -> Json {
        match self {
            SearchEvent::Started {
                first_episode,
                episodes,
                base_latency_s,
                base_accuracy,
                backend,
            } => Json::obj(vec![
                ("type", Json::str("started")),
                ("first_episode", Json::num(*first_episode as f64)),
                ("episodes", Json::num(*episodes as f64)),
                ("base_latency_s", Json::num(*base_latency_s)),
                ("base_accuracy", Json::num(*base_accuracy)),
                ("backend", Json::str(backend.clone())),
            ]),
            SearchEvent::EpisodeFinished(s) => Json::obj(vec![
                ("type", Json::str("episode")),
                ("summary", s.to_json()),
            ]),
            SearchEvent::BestImproved(s) => Json::obj(vec![
                ("type", Json::str("best")),
                ("summary", s.to_json()),
            ]),
            SearchEvent::Finished {
                episodes,
                best_reward,
                cache_hits,
                cache_misses,
            } => Json::obj(vec![
                ("type", Json::str("finished")),
                ("episodes", Json::num(*episodes as f64)),
                ("best_reward", Json::num(*best_reward)),
                ("cache_hits", Json::num(*cache_hits as f64)),
                ("cache_misses", Json::num(*cache_misses as f64)),
            ]),
        }
    }
}

/// A sink for [`SearchEvent`]s.  Implemented for every
/// `FnMut(&SearchEvent)` closure, so `driver.add_observer(|e| ...)` works
/// directly.
pub trait SearchObserver {
    /// Receive one event.  Called synchronously from the driver.
    fn on_event(&mut self, event: &SearchEvent);
}

impl<F: FnMut(&SearchEvent)> SearchObserver for F {
    fn on_event(&mut self, event: &SearchEvent) {
        self(event)
    }
}

/// What one [`SearchDriver::step`] call did.
#[derive(Clone, Debug)]
pub enum StepOutcome {
    /// One layer decision was applied; the episode continues.
    Stepped {
        /// The episode the step belongs to.
        episode: usize,
        /// Layer decisions taken so far in this episode.
        step: usize,
    },
    /// The episode's policy was validated and the agent optimized.
    EpisodeFinished(EpisodeSummary),
    /// Every episode has already run; see [`SearchDriver::outcome`].
    SearchComplete,
}

/// Typed construction of a [`SearchDriver`] — every knob of
/// [`SearchConfig`] as a method, replacing stringly-typed JSON plumbing.
///
/// ```no_run
/// # use galen::agent::{mapper_for, AgentKind};
/// # use galen::search::{SearchBuilder, SimEvaluator};
/// # fn demo(ir: &galen::model::ModelIr, sens: &galen::eval::SensitivityTable,
/// #         latency: &mut dyn galen::hw::LatencyProvider) -> anyhow::Result<()> {
/// let ev = SimEvaluator::new(ir);
/// let mapper = mapper_for(AgentKind::Joint);
/// let outcome = SearchBuilder::new(AgentKind::Joint, 0.3)
///     .episodes(60)
///     .seed(11)
///     .build(ir, sens, &ev, latency, mapper.as_ref())?
///     .run_to_completion()?;
/// # Ok(())
/// # }
/// ```
#[derive(Clone, Debug)]
pub struct SearchBuilder {
    cfg: SearchConfig,
    base: Option<DiscretePolicy>,
}

impl SearchBuilder {
    /// A builder at the CPU-budget defaults for `agent` towards `target`.
    pub fn new(agent: crate::agent::AgentKind, target: f64) -> Self {
        Self::from_config(SearchConfig::new(agent, target))
    }

    /// A builder starting from an existing configuration.
    pub fn from_config(cfg: SearchConfig) -> Self {
        Self { cfg, base: None }
    }

    /// Total episodes to run.
    pub fn episodes(mut self, n: usize) -> Self {
        self.cfg.episodes = n;
        self
    }

    /// Random warm-up episodes that fill the replay buffer.
    pub fn warmup_episodes(mut self, n: usize) -> Self {
        self.cfg.warmup_episodes = n;
        self
    }

    /// Agent optimization steps per post-warmup episode.
    pub fn opt_steps_per_episode(mut self, n: usize) -> Self {
        self.cfg.opt_steps_per_episode = n;
        self
    }

    /// Validation batches per accuracy evaluation.
    pub fn eval_batches(mut self, n: usize) -> Self {
        self.cfg.eval_batches = n;
        self
    }

    /// RNG seed (forked per subsystem).
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Reward cost exponent beta (< 0).
    pub fn beta(mut self, beta: f64) -> Self {
        self.cfg.beta = beta;
        self
    }

    /// Which reward family scores episodes.
    pub fn reward(mut self, spec: RewardSpec) -> Self {
        self.cfg.reward = spec;
        self
    }

    /// DDPG hyper-parameters.
    pub fn ddpg(mut self, ddpg: crate::agent::DdpgConfig) -> Self {
        self.cfg.ddpg = ddpg;
        self
    }

    /// Progress-log cadence (0 = silent).
    pub fn log_every(mut self, n: usize) -> Self {
        self.cfg.log_every = n;
        self
    }

    /// Start every episode from this pre-compressed policy instead of the
    /// uncompressed reference (sequential two-stage schemes).
    pub fn base_policy(mut self, base: DiscretePolicy) -> Self {
        self.base = Some(base);
        self
    }

    /// The configuration assembled so far.
    pub fn config(&self) -> &SearchConfig {
        &self.cfg
    }

    /// Wire the builder to a concrete environment and produce the driver.
    ///
    /// `mapper.kind()` must match the configured agent — the driver refuses
    /// mismatched wiring instead of silently searching the wrong space.
    pub fn build<'a>(
        self,
        ir: &'a ModelIr,
        sens: &'a SensitivityTable,
        evaluator: &'a dyn PolicyEvaluator,
        latency: &'a mut dyn LatencyProvider,
        mapper: &'a dyn PolicyMapper,
    ) -> Result<SearchDriver<'a>> {
        let Self { cfg, base } = self;
        anyhow::ensure!(
            mapper.kind() == cfg.agent,
            "mapper implements the {} agent but the config asks for {}",
            mapper.kind(),
            cfg.agent
        );
        anyhow::ensure!(cfg.episodes > 0, "a search needs at least one episode");
        // reject invalid reward shapes here (Result), not in the reward
        // constructors (assert) — serve workers must never panic on a bad
        // client spec
        anyhow::ensure!(
            cfg.beta < 0.0,
            "reward cost exponent beta must be negative (got {})",
            cfg.beta
        );
        anyhow::ensure!(
            cfg.target > 0.0,
            "target compression rate must be positive (got {})",
            cfg.target
        );
        if let RewardSpec::HardExponential { w } = cfg.reward {
            anyhow::ensure!(
                w < 0.0,
                "hard-exponential exponent w must be negative (got {w})"
            );
        }
        // ... and the DDPG knobs whose constructors assert (ReplayBuffer
        // capacity, Ema smoothing) — same no-panic contract
        anyhow::ensure!(
            cfg.ddpg.replay_capacity > 0,
            "ddpg replay_capacity must be at least 1"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.ddpg.reward_ema),
            "ddpg reward_ema must be in [0, 1] (got {})",
            cfg.ddpg.reward_ema
        );
        let steps = mapper.steps(ir);
        anyhow::ensure!(!steps.is_empty(), "mapper yields no actionable layers");
        let sb = StateBuilder::new(ir, sens, mapper.action_dim());
        let agent = Ddpg::new(sb.dim(), mapper.action_dim(), cfg.ddpg.clone(), cfg.seed);
        let reference = DiscretePolicy::reference(ir);
        let base_latency_s = latency.latency(ir, &reference);
        let reward = cfg.reward.build(cfg.beta, cfg.target, base_latency_s);
        let base_accuracy = evaluator.base_accuracy();
        let episodes = cfg.episodes;
        let metrics = DriverMetrics::for_agent(cfg.agent);
        Ok(SearchDriver {
            ir,
            sens,
            evaluator,
            latency,
            mapper,
            cfg,
            reward,
            sb,
            steps,
            agent,
            base,
            base_latency_s,
            base_accuracy,
            episode: 0,
            history: Vec::with_capacity(episodes),
            best: None,
            cur: None,
            observers: Vec::new(),
            started_emitted: false,
            finished_emitted: false,
            metrics,
        })
    }
}

/// Registry handles for the driver's metric series, resolved once per
/// driver against the process-wide `obs` registry and labeled by agent
/// kind so concurrent sweep jobs searching different spaces keep separate
/// series.  Deliberately *not* part of the checkpoint format —
/// observability state never enters the schema, so checkpoints taken with
/// metrics on and off stay bit-identical.
struct DriverMetrics {
    steps: obs::Counter,
    episodes: obs::Counter,
    last_reward: obs::Gauge,
    best_reward: obs::Gauge,
    checkpoint_write_seconds: obs::Histogram,
}

impl DriverMetrics {
    fn for_agent(agent: crate::agent::AgentKind) -> Self {
        let a = agent.to_string();
        let labels: &[(&str, &str)] = &[("agent", &a)];
        DriverMetrics {
            steps: obs::Counter::register("search_steps_total", labels),
            episodes: obs::Counter::register("search_episodes_total", labels),
            last_reward: obs::Gauge::register("search_last_reward", labels),
            best_reward: obs::Gauge::register("search_best_reward", labels),
            checkpoint_write_seconds: obs::Histogram::register(
                "search_checkpoint_write_seconds",
                labels,
                &obs::latency_bounds(),
            ),
        }
    }
}

/// Mid-episode scratch: the partial policy plus the trajectory recorded so
/// far.  Exists only between the first and last `step()` of an episode.
struct EpisodeInProgress {
    random: bool,
    policy: DiscretePolicy,
    states: Vec<Vec<f32>>,
    actions: Vec<Vec<f32>>,
    prev_action: Vec<f32>,
    k: usize,
}

/// The resumable policy-search state machine (see the module docs).
pub struct SearchDriver<'a> {
    ir: &'a ModelIr,
    sens: &'a SensitivityTable,
    evaluator: &'a dyn PolicyEvaluator,
    latency: &'a mut dyn LatencyProvider,
    mapper: &'a dyn PolicyMapper,
    cfg: SearchConfig,
    reward: Box<dyn RewardModel>,
    sb: StateBuilder,
    steps: Vec<usize>,
    agent: Ddpg,
    base: Option<DiscretePolicy>,
    base_latency_s: f64,
    base_accuracy: f64,
    episode: usize,
    history: Vec<EpisodeSummary>,
    best: Option<(EpisodeSummary, DiscretePolicy)>,
    cur: Option<EpisodeInProgress>,
    observers: Vec<Box<dyn SearchObserver + 'a>>,
    started_emitted: bool,
    finished_emitted: bool,
    metrics: DriverMetrics,
}

impl<'a> SearchDriver<'a> {
    /// Register an event sink; every subsequent event reaches it.
    pub fn add_observer(&mut self, observer: impl SearchObserver + 'a) {
        self.observers.push(Box::new(observer));
    }

    /// The configuration the driver runs.
    pub fn config(&self) -> &SearchConfig {
        &self.cfg
    }

    /// Episodes finished so far.
    pub fn episode(&self) -> usize {
        self.episode
    }

    /// Whether every configured episode has run.
    pub fn is_done(&self) -> bool {
        self.episode >= self.cfg.episodes
    }

    /// Whether an episode is currently in flight (between its first and
    /// last layer decision) — checkpoints are refused in this state.
    pub fn mid_episode(&self) -> bool {
        self.cur.is_some()
    }

    /// Per-episode summaries of every finished episode, in order.
    pub fn history(&self) -> &[EpisodeSummary] {
        &self.history
    }

    /// Summary of the best (highest-reward) episode so far.
    pub fn best(&self) -> Option<&EpisodeSummary> {
        self.best.as_ref().map(|(s, _)| s)
    }

    /// Reference (uncompressed) latency the search normalizes against.
    pub fn base_latency_s(&self) -> f64 {
        self.base_latency_s
    }

    fn emit(&mut self, event: &SearchEvent) {
        for obs in &mut self.observers {
            obs.on_event(event);
        }
    }

    /// Advance the search by one layer decision.  When the decision
    /// completes an episode, the policy is validated (accuracy + latency),
    /// the shared episode reward is stored across the trajectory, and the
    /// agent optimizes — exactly the work the monolithic loop did, at the
    /// same point in the RNG streams.
    pub fn step(&mut self) -> Result<StepOutcome> {
        if self.is_done() {
            self.emit_finished();
            return Ok(StepOutcome::SearchComplete);
        }
        if !self.started_emitted {
            self.started_emitted = true;
            let ev = SearchEvent::Started {
                first_episode: self.episode,
                episodes: self.cfg.episodes,
                base_latency_s: self.base_latency_s,
                base_accuracy: self.base_accuracy,
                backend: self.latency.backend().to_string(),
            };
            self.emit(&ev);
        }
        if self.cur.is_none() {
            self.cur = Some(EpisodeInProgress {
                random: self.episode < self.cfg.warmup_episodes,
                policy: self
                    .base
                    .clone()
                    .unwrap_or_else(|| DiscretePolicy::reference(self.ir)),
                states: Vec::with_capacity(self.steps.len()),
                actions: Vec::with_capacity(self.steps.len()),
                prev_action: vec![0.0f32; self.mapper.action_dim()],
                k: 0,
            });
        }
        {
            let ep = self.cur.as_mut().expect("episode just ensured");
            let idx = self.steps[ep.k];
            let s = self.sb.build(
                self.ir,
                self.sens,
                &ep.policy,
                idx,
                ep.k,
                self.steps.len(),
                &ep.prev_action,
            );
            let a = self.agent.act(&s, true, ep.random);
            self.mapper.apply(self.ir, &mut ep.policy, idx, &a);
            ep.prev_action.copy_from_slice(&a);
            ep.states.push(s);
            ep.actions.push(a);
            ep.k += 1;
            self.metrics.steps.inc();
            if ep.k < self.steps.len() {
                return Ok(StepOutcome::Stepped {
                    episode: self.episode,
                    step: ep.k,
                });
            }
        }
        let summary = self.finish_episode()?;
        Ok(StepOutcome::EpisodeFinished(summary))
    }

    /// Validate the completed episode and fold it into the agent.
    fn finish_episode(&mut self) -> Result<EpisodeSummary> {
        let _sp = obs::trace::span("episode")
            .arg("agent", self.cfg.agent.to_string())
            .arg("episode", self.episode.to_string());
        let ep = self.cur.take().expect("an episode is in flight");
        // ---- validate the complete policy (paper Fig. 1) ----
        let accuracy = self.evaluator.accuracy(&ep.policy)?;
        let measured = self.latency.measure(self.ir, &ep.policy).latency_s;
        let reward = self.reward.reward(accuracy, measured);

        // ---- shared per-episode reward across all transitions ----
        let n = ep.states.len();
        for t in 0..n {
            let terminal = t + 1 == n;
            let next_state = if terminal {
                vec![0.0; ep.states[t].len()]
            } else {
                ep.states[t + 1].clone()
            };
            self.agent.store(Transition {
                state: ep.states[t].clone(),
                action: ep.actions[t].clone(),
                reward: reward as f32,
                next_state,
                terminal,
            });
        }
        self.agent.end_episode();
        if !ep.random {
            for _ in 0..self.cfg.opt_steps_per_episode {
                self.agent.optimize();
            }
        }

        let summary = EpisodeSummary {
            episode: self.episode,
            reward,
            accuracy,
            latency_s: measured,
            macs: ep.policy.macs(self.ir),
            bops: ep.policy.bops(self.ir),
        };
        let improved = self
            .best
            .as_ref()
            .map(|(b, _)| reward > b.reward)
            .unwrap_or(true);
        if improved {
            self.best = Some((summary.clone(), ep.policy.clone()));
        }
        if self.cfg.log_every > 0
            && (self.episode % self.cfg.log_every == 0 || self.episode + 1 == self.cfg.episodes)
        {
            log::info!(
                "[{} c={:.2}] ep {:4} reward={reward:+.4} acc={accuracy:.4} lat={:.2}ms ({:.1}% of base) sigma={:.3}",
                self.mapper.kind(),
                self.cfg.target,
                self.episode,
                measured * 1e3,
                100.0 * measured / self.base_latency_s,
                self.agent.sigma,
            );
        }
        self.metrics.episodes.inc();
        self.metrics.last_reward.set(reward);
        if improved {
            self.metrics.best_reward.set(reward);
        }
        self.history.push(summary.clone());
        self.episode += 1;
        let ev = SearchEvent::EpisodeFinished(summary.clone());
        self.emit(&ev);
        if improved {
            let ev = SearchEvent::BestImproved(summary.clone());
            self.emit(&ev);
        }
        if self.is_done() {
            self.emit_finished();
        }
        Ok(summary)
    }

    fn emit_finished(&mut self) {
        if self.finished_emitted {
            return;
        }
        self.finished_emitted = true;
        let (hits, misses) = self.latency.cache_stats();
        log::debug!(
            "search done: {} latency cache {hits} hits / {misses} misses ({:.1}% hit rate)",
            self.latency.backend(),
            100.0 * hits as f64 / (hits + misses).max(1) as f64
        );
        let best_reward = self.best.as_ref().map(|(s, _)| s.reward).unwrap_or(f64::NAN);
        let ev = SearchEvent::Finished {
            episodes: self.episode,
            best_reward,
            cache_hits: hits,
            cache_misses: misses,
        };
        self.emit(&ev);
    }

    /// Run steps until the current episode finishes.  Returns `None` when
    /// every episode has already run.
    pub fn run_episode(&mut self) -> Result<Option<EpisodeSummary>> {
        loop {
            match self.step()? {
                StepOutcome::Stepped { .. } => continue,
                StepOutcome::EpisodeFinished(summary) => return Ok(Some(summary)),
                StepOutcome::SearchComplete => return Ok(None),
            }
        }
    }

    /// Run every remaining episode and return the outcome.
    pub fn run_to_completion(&mut self) -> Result<SearchOutcome> {
        while self.run_episode()?.is_some() {}
        self.outcome()
    }

    /// The search result.  Only available once every episode has run.
    pub fn outcome(&self) -> Result<SearchOutcome> {
        anyhow::ensure!(
            self.is_done(),
            "search outcome requested after {} of {} episodes",
            self.episode,
            self.cfg.episodes
        );
        let (best, best_policy) = self.best.clone().expect("at least one episode ran");
        Ok(SearchOutcome {
            best_policy,
            best,
            history: self.history.clone(),
            base_latency_s: self.base_latency_s,
            base_accuracy: self.base_accuracy,
            latency_backend: self.latency.backend().to_string(),
        })
    }

    // ---------------- checkpoint / resume ----------------

    /// Serialize the complete search state into a schema-versioned JSON
    /// document.  Only legal at an episode boundary — mid-episode scratch
    /// (partial policies, un-stored trajectories) is deliberately not part
    /// of the checkpoint format.
    ///
    /// The document captures the full config, progress (history, best
    /// policy, reference latency/accuracy), and the agent's entire learning
    /// state including its live RNG stream — a driver rebuilt from it via
    /// [`SearchDriver::resume_from`] continues bit-identically to a run
    /// that was never interrupted.
    pub fn save_checkpoint(&self) -> Result<Json> {
        anyhow::ensure!(
            self.cur.is_none(),
            "checkpoints are episode-aligned: finish the in-flight episode first \
             (run_episode) and retry"
        );
        let best = match &self.best {
            None => Json::Null,
            Some((summary, policy)) => Json::obj(vec![
                ("summary", summary.to_json()),
                ("policy", policy.to_json()),
            ]),
        };
        Ok(Json::obj(vec![
            ("schema_version", Json::num(CHECKPOINT_SCHEMA_VERSION as f64)),
            ("kind", Json::str(CHECKPOINT_KIND)),
            ("config", self.cfg.to_checkpoint_json()),
            ("episode", Json::num(self.episode as f64)),
            ("base_latency_s", Json::num(self.base_latency_s)),
            ("base_accuracy", Json::num(self.base_accuracy)),
            (
                "base_policy",
                match &self.base {
                    None => Json::Null,
                    Some(p) => p.to_json(),
                },
            ),
            (
                "history",
                Json::Arr(self.history.iter().map(|h| h.to_json()).collect()),
            ),
            ("best", best),
            ("agent", self.agent.checkpoint()),
        ]))
    }

    /// [`SearchDriver::save_checkpoint`] straight to a file.  The write
    /// latency (serialize + atomic write) feeds the registry's
    /// `search_checkpoint_write_seconds` histogram.
    pub fn write_checkpoint(&self, path: &Path) -> Result<()> {
        let _sp = obs::trace::span("checkpoint_write");
        let t0 = Instant::now();
        let res = self.save_checkpoint()?.write_file(path);
        self.metrics
            .checkpoint_write_seconds
            .observe_duration(t0.elapsed());
        res
    }

    /// Rebuild a driver from a checkpoint document and a concrete
    /// environment.  The environment must match the one the checkpoint was
    /// taken in (same model, same mapper kind, a latency backend whose
    /// estimates are reproducible — the simulator's are pure functions of
    /// its seed); the configuration travels inside the checkpoint.
    pub fn resume_from(
        checkpoint: &Json,
        ir: &'a ModelIr,
        sens: &'a SensitivityTable,
        evaluator: &'a dyn PolicyEvaluator,
        latency: &'a mut dyn LatencyProvider,
        mapper: &'a dyn PolicyMapper,
    ) -> Result<SearchDriver<'a>> {
        anyhow::ensure!(
            checkpoint.req_str("kind")? == CHECKPOINT_KIND,
            "not a search checkpoint document"
        );
        anyhow::ensure!(
            checkpoint.req_usize("schema_version")? == CHECKPOINT_SCHEMA_VERSION,
            "checkpoint schema version mismatch (have {}, support {})",
            checkpoint.req_usize("schema_version")?,
            CHECKPOINT_SCHEMA_VERSION
        );
        let cfg = SearchConfig::from_checkpoint_json(checkpoint.req("config")?)?;
        anyhow::ensure!(
            cfg.episodes > 0,
            "checkpoint config has a zero-episode search"
        );
        anyhow::ensure!(
            mapper.kind() == cfg.agent,
            "mapper implements the {} agent but the checkpoint was taken with {}",
            mapper.kind(),
            cfg.agent
        );
        let steps = mapper.steps(ir);
        anyhow::ensure!(!steps.is_empty(), "mapper yields no actionable layers");
        let sb = StateBuilder::new(ir, sens, mapper.action_dim());
        let agent = Ddpg::restore(checkpoint.req("agent")?)?;
        anyhow::ensure!(
            agent.state_dim() == sb.dim() && agent.action_dim() == mapper.action_dim(),
            "checkpoint agent dimensions do not match this model/mapper \
             (state {}x{} vs {}x{})",
            agent.state_dim(),
            agent.action_dim(),
            sb.dim(),
            mapper.action_dim()
        );
        let base = match checkpoint.req("base_policy")? {
            Json::Null => None,
            p => Some(DiscretePolicy::from_json(p)?),
        };
        if let Some(p) = &base {
            anyhow::ensure!(
                p.layers.len() == ir.layers.len(),
                "checkpoint base policy does not match this model"
            );
        }
        let base_latency_s = checkpoint.req_f64("base_latency_s")?;
        let base_accuracy = checkpoint.req_f64("base_accuracy")?;
        let w_ok = match cfg.reward {
            RewardSpec::Absolute => true,
            RewardSpec::HardExponential { w } => w < 0.0,
        };
        anyhow::ensure!(
            w_ok && cfg.beta < 0.0 && cfg.target > 0.0 && base_latency_s > 0.0,
            "checkpoint carries an invalid reward shape \
             (beta {}, target {}, base latency {})",
            cfg.beta,
            cfg.target,
            base_latency_s
        );
        let reward = cfg.reward.build(cfg.beta, cfg.target, base_latency_s);
        let episode = checkpoint.req_usize("episode")?;
        anyhow::ensure!(
            episode <= cfg.episodes,
            "checkpoint records episode {episode} past its {}-episode budget",
            cfg.episodes
        );
        let mut history = Vec::with_capacity(cfg.episodes);
        for h in checkpoint.req_arr("history")? {
            history.push(EpisodeSummary::from_json(h)?);
        }
        anyhow::ensure!(
            history.len() == episode,
            "checkpoint history has {} entries but records episode {}",
            history.len(),
            episode
        );
        let best = match checkpoint.req("best")? {
            Json::Null => None,
            b => Some((
                EpisodeSummary::from_json(b.req("summary")?)?,
                DiscretePolicy::from_json(b.req("policy")?)?,
            )),
        };
        anyhow::ensure!(
            best.is_some() || episode == 0,
            "checkpoint past episode 0 must carry a best policy"
        );
        let metrics = DriverMetrics::for_agent(cfg.agent);
        Ok(SearchDriver {
            ir,
            sens,
            evaluator,
            latency,
            mapper,
            cfg,
            reward,
            sb,
            steps,
            agent,
            base,
            base_latency_s,
            base_accuracy,
            episode,
            history,
            best,
            cur: None,
            observers: Vec::new(),
            started_emitted: false,
            finished_emitted: false,
            metrics,
        })
    }

    /// [`SearchDriver::resume_from`] straight from a file written by
    /// [`SearchDriver::write_checkpoint`].
    pub fn resume_from_file(
        path: &Path,
        ir: &'a ModelIr,
        sens: &'a SensitivityTable,
        evaluator: &'a dyn PolicyEvaluator,
        latency: &'a mut dyn LatencyProvider,
        mapper: &'a dyn PolicyMapper,
    ) -> Result<SearchDriver<'a>> {
        Self::resume_from(&Json::read_file(path)?, ir, sens, evaluator, latency, mapper)
    }
}

/// Cheap integrity check of a checkpoint document against the
/// configuration a resume expects, without building a driver (no model,
/// evaluator, or latency backend needed).  Callers that fall back to a
/// fresh search on a bad checkpoint (`galen serve --resume-jobs` after a
/// crash mid-write) probe with this first, so the errors
/// [`SearchDriver::resume_from`] raises stay hard.
pub fn validate_checkpoint(checkpoint: &Json, cfg: &SearchConfig) -> Result<()> {
    anyhow::ensure!(
        checkpoint.req_str("kind")? == CHECKPOINT_KIND,
        "not a search checkpoint document"
    );
    anyhow::ensure!(
        checkpoint.req_usize("schema_version")? == CHECKPOINT_SCHEMA_VERSION,
        "checkpoint schema version mismatch (have {}, support {})",
        checkpoint.req_usize("schema_version")?,
        CHECKPOINT_SCHEMA_VERSION
    );
    let ck_cfg = SearchConfig::from_checkpoint_json(checkpoint.req("config")?)?;
    anyhow::ensure!(
        ck_cfg.to_checkpoint_json().dump() == cfg.to_checkpoint_json().dump(),
        "checkpoint was taken with a different search configuration"
    );
    let episode = checkpoint.req_usize("episode")?;
    anyhow::ensure!(
        episode <= cfg.episodes,
        "checkpoint records episode {episode} past its {}-episode budget",
        cfg.episodes
    );
    let history = checkpoint.req_arr("history")?.len();
    anyhow::ensure!(
        history == episode,
        "checkpoint history has {history} entries but records episode {episode}"
    );
    // the agent blob must at least restore; dimension checks against the
    // live model happen in resume_from
    Ddpg::restore(checkpoint.req("agent")?)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::{mapper_for, AgentKind, DdpgConfig};
    use crate::eval::SensitivityConfig;
    use crate::hw::{CostModel, HwTarget, LatencySimulator};
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::search::SimEvaluator;

    fn setup() -> (ModelIr, SensitivityTable) {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sens =
            SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
        (ir, sens)
    }

    fn sim(seed: u64) -> LatencySimulator {
        LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), seed)
    }

    fn cfg(agent: AgentKind, episodes: usize) -> SearchConfig {
        let mut cfg = SearchConfig::fast(agent, 0.5);
        cfg.episodes = episodes;
        cfg.warmup_episodes = 3;
        cfg.opt_steps_per_episode = 4;
        cfg.log_every = 0;
        cfg.ddpg = DdpgConfig {
            hidden: (24, 16),
            batch: 16,
            replay_capacity: 256,
            ..Default::default()
        };
        cfg
    }

    #[test]
    fn builder_rejects_mismatched_mapper() {
        let (ir, sens) = setup();
        let ev = SimEvaluator::new(&ir);
        let mut s = sim(1);
        let mapper = mapper_for(AgentKind::Pruning);
        let err = SearchBuilder::from_config(cfg(AgentKind::Joint, 4))
            .build(&ir, &sens, &ev, &mut s, mapper.as_ref())
            .err()
            .expect("mismatched mapper must be rejected");
        assert!(format!("{err:#}").contains("pruning"));
    }

    #[test]
    fn builder_typed_knobs_reach_the_config() {
        let b = SearchBuilder::new(AgentKind::Joint, 0.4)
            .episodes(9)
            .warmup_episodes(2)
            .opt_steps_per_episode(5)
            .eval_batches(3)
            .seed(42)
            .beta(-2.0)
            .reward(RewardSpec::HardExponential { w: -2.0 })
            .log_every(0);
        let c = b.config();
        assert_eq!(c.episodes, 9);
        assert_eq!(c.warmup_episodes, 2);
        assert_eq!(c.opt_steps_per_episode, 5);
        assert_eq!(c.eval_batches, 3);
        assert_eq!(c.seed, 42);
        assert_eq!(c.beta, -2.0);
        assert_eq!(c.reward, RewardSpec::HardExponential { w: -2.0 });
        assert_eq!(c.target, 0.4);
    }

    #[test]
    fn event_stream_is_complete_and_ordered() {
        let (ir, sens) = setup();
        let ev = SimEvaluator::new(&ir);
        let mut s = sim(7);
        let mapper = mapper_for(AgentKind::Quantization);
        let events = std::rc::Rc::new(std::cell::RefCell::new(Vec::<String>::new()));
        let sink = events.clone();
        let mut driver = SearchBuilder::from_config(cfg(AgentKind::Quantization, 6))
            .build(&ir, &sens, &ev, &mut s, mapper.as_ref())
            .unwrap();
        driver.add_observer(move |e: &SearchEvent| {
            sink.borrow_mut().push(match e {
                SearchEvent::Started { .. } => "started".to_string(),
                SearchEvent::EpisodeFinished(s) => format!("episode{}", s.episode),
                SearchEvent::BestImproved(_) => "best".to_string(),
                SearchEvent::Finished { episodes, .. } => format!("finished{episodes}"),
            });
        });
        driver.run_to_completion().unwrap();
        let log = events.borrow();
        assert_eq!(log.first().unwrap(), "started");
        assert_eq!(log.last().unwrap(), "finished6");
        assert_eq!(log.iter().filter(|e| *e == "started").count(), 1);
        assert_eq!(log.iter().filter(|e| e.starts_with("finished")).count(), 1);
        let episodes: Vec<&String> = log.iter().filter(|e| e.starts_with("episode")).collect();
        assert_eq!(episodes.len(), 6);
        assert_eq!(episodes[0], "episode0");
        assert_eq!(episodes[5], "episode5");
        // episode 0 is always an improvement
        assert!(log.iter().any(|e| e == "best"));
    }

    #[test]
    fn event_jsons_carry_type_tags() {
        let s = EpisodeSummary {
            episode: 1,
            reward: 0.5,
            accuracy: 0.9,
            latency_s: 0.01,
            macs: 100,
            bops: 200,
        };
        for (ev, tag) in [
            (
                SearchEvent::Started {
                    first_episode: 0,
                    episodes: 5,
                    base_latency_s: 0.1,
                    base_accuracy: 0.9,
                    backend: "sim".into(),
                },
                "started",
            ),
            (SearchEvent::EpisodeFinished(s.clone()), "episode"),
            (SearchEvent::BestImproved(s), "best"),
            (
                SearchEvent::Finished {
                    episodes: 5,
                    best_reward: 0.5,
                    cache_hits: 1,
                    cache_misses: 2,
                },
                "finished",
            ),
        ] {
            assert_eq!(ev.to_json().req_str("type").unwrap(), tag);
        }
    }

    #[test]
    fn checkpoint_mid_episode_is_refused() {
        let (ir, sens) = setup();
        let ev = SimEvaluator::new(&ir);
        let mut s = sim(3);
        let mapper = mapper_for(AgentKind::Joint);
        let mut driver = SearchBuilder::from_config(cfg(AgentKind::Joint, 4))
            .build(&ir, &sens, &ev, &mut s, mapper.as_ref())
            .unwrap();
        // boundary: fine
        driver.save_checkpoint().unwrap();
        // one layer decision in: refused
        match driver.step().unwrap() {
            StepOutcome::Stepped { .. } => {}
            other => panic!("expected a mid-episode step, got {other:?}"),
        }
        assert!(driver.mid_episode());
        assert!(driver.save_checkpoint().is_err());
        // episode boundary again: fine
        while driver.mid_episode() {
            driver.step().unwrap();
        }
        driver.save_checkpoint().unwrap();
    }

    #[test]
    fn resume_rejects_wrong_documents() {
        let (ir, sens) = setup();
        let ev = SimEvaluator::new(&ir);
        let mapper = mapper_for(AgentKind::Joint);
        let mut s = sim(3);
        let driver = SearchBuilder::from_config(cfg(AgentKind::Joint, 4))
            .build(&ir, &sens, &ev, &mut s, mapper.as_ref())
            .unwrap();
        let good = driver.save_checkpoint().unwrap();
        drop(driver);

        // wrong schema version
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.insert("schema_version".into(), Json::num(999.0));
        }
        let mut s2 = sim(3);
        assert!(
            SearchDriver::resume_from(&bad, &ir, &sens, &ev, &mut s2, mapper.as_ref()).is_err()
        );

        // wrong mapper for the checkpointed agent
        let wrong = mapper_for(AgentKind::Pruning);
        let mut s3 = sim(3);
        assert!(
            SearchDriver::resume_from(&good, &ir, &sens, &ev, &mut s3, wrong.as_ref()).is_err()
        );

        // not a checkpoint at all
        let mut s4 = sim(3);
        assert!(SearchDriver::resume_from(
            &Json::obj(vec![("kind", Json::str("something_else"))]),
            &ir,
            &sens,
            &ev,
            &mut s4,
            mapper.as_ref()
        )
        .is_err());
    }
}
