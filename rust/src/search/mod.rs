//! The episode loop (paper Figures 1 + 2) and the sweep orchestrator.
//!
//! `run_search` predicts a full policy layer by layer, validates it
//! (accuracy on the PJRT artifact + latency on the pluggable hardware
//! backend), computes the absolute reward, shares it across the episode's
//! transitions, and optimizes the agent.
//!
//! `orchestrator` fans whole grids of `(agent, latency target)` searches
//! out across worker threads and folds the outcomes into a Pareto front —
//! see `run_sweep` / `coordinator::Session::sweep_parallel`.

mod config;
mod episode;
mod orchestrator;

pub use config::SearchConfig;
pub use episode::{
    quant_histogram, run_search, EpisodeSummary, PolicyEvaluator, SearchOutcome, SimEvaluator,
};
pub use orchestrator::{
    job_seed, run_sweep, LatencyFactory, ParetoFront, ParetoPoint, SweepGrid, SweepJob,
    SweepOutcome, SweepReport, SWEEP_SCHEMA_VERSION,
};
