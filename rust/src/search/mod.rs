//! The search subsystem: the resumable episode-loop driver (paper Figures
//! 1 + 2), its one-call wrapper, and the sweep orchestrator.
//!
//! `SearchDriver` (built through the typed `SearchBuilder`) predicts a full
//! policy layer by layer, validates it (accuracy on the PJRT artifact +
//! latency on the pluggable hardware backend), computes the reward, shares
//! it across the episode's transitions, and optimizes the agent — with
//! explicit `step()`/`run_episode()` granularity, a `SearchEvent` observer
//! stream, and schema-versioned checkpoint/resume whose resumed runs are
//! bit-identical to uninterrupted ones.
//!
//! `run_search` wraps the driver for callers that want the original
//! blocking one-call API; `orchestrator` fans whole grids of
//! `(agent, latency target)` searches out across worker threads and folds
//! the outcomes into a Pareto front — see `run_sweep` /
//! `coordinator::Session::sweep_parallel`.  The `coordinator::serve` job
//! service multiplexes many concurrent drivers over the same machinery.

mod config;
mod driver;
mod episode;
mod orchestrator;

pub use config::SearchConfig;
pub use driver::{
    validate_checkpoint, SearchBuilder, SearchDriver, SearchEvent, SearchObserver, StepOutcome,
    CHECKPOINT_SCHEMA_VERSION,
};
pub use episode::{
    quant_histogram, run_search, EpisodeSummary, PolicyEvaluator, SearchOutcome, SimEvaluator,
};
pub use orchestrator::{
    job_seed, run_sweep, LatencyFactory, ParetoFront, ParetoPoint, SweepGrid, SweepJob,
    SweepOutcome, SweepReport, SWEEP_SCHEMA_VERSION,
};
