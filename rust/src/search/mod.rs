//! The episode loop (paper Figures 1 + 2): predict a full policy layer by
//! layer, validate it (accuracy on the PJRT artifact + latency on the
//! hardware simulator), compute the absolute reward, share it across the
//! episode's transitions, and optimize the agent.

mod config;
mod episode;

pub use config::SearchConfig;
pub use episode::{
    quant_histogram, run_search, EpisodeSummary, PolicyEvaluator, SearchOutcome, SimEvaluator,
};
