//! Search configuration (paper defaults + CPU-budget scaling).

use anyhow::Result;

use crate::agent::{AgentKind, DdpgConfig};
use crate::reward::RewardSpec;
use crate::util::json::Json;

/// Hyper-parameters of one policy search.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Which agent runs the search.
    pub agent: AgentKind,
    /// Target compression rate c (fraction of the original latency).
    pub target: f64,
    /// Reward cost exponent beta (paper: -3.0).
    pub beta: f64,
    /// Which reward family scores episodes (default: the absolute reward).
    pub reward: RewardSpec,
    /// Total episodes (paper: 310 quantization, 410 pruning/joint).
    pub episodes: usize,
    /// Random warm-up episodes filling the replay buffer (paper: 10).
    pub warmup_episodes: usize,
    /// Agent optimization steps per post-warmup episode.
    pub opt_steps_per_episode: usize,
    /// Validation batches per accuracy evaluation.
    pub eval_batches: usize,
    /// RNG seed (forked per subsystem).
    pub seed: u64,
    /// DDPG agent hyper-parameters.
    pub ddpg: DdpgConfig,
    /// Log a progress line every N episodes (0 = silent).
    pub log_every: usize,
}

/// Keys `apply_json` accepts at the top level (unknown keys are an error).
const CONFIG_KEYS: &[&str] = &[
    "target",
    "beta",
    "reward",
    "reward_w",
    "episodes",
    "warmup_episodes",
    "opt_steps_per_episode",
    "eval_batches",
    "seed",
    "log_every",
    "ddpg",
];

/// Keys `apply_json` accepts inside the `ddpg` block.
const DDPG_KEYS: &[&str] = &[
    "hidden",
    "actor_lr",
    "critic_lr",
    "gamma",
    "tau",
    "batch",
    "replay_capacity",
    "sigma0",
    "sigma_decay",
    "reward_ema",
    "grad_clip",
];

impl SearchConfig {
    /// CPU-budget defaults: 120 episodes with a rescaled exploration decay.
    pub fn new(agent: AgentKind, target: f64) -> Self {
        let mut ddpg = DdpgConfig::default();
        // The paper's sigma decay (0.95/episode) is tuned for 310-410
        // episodes; at this CPU-budget default of 120 episodes it would
        // collapse exploration by ep ~40 and strand the agent in early
        // local optima.  Scale the decay so sigma ends near 0.02.
        ddpg.sigma_decay = 0.975;
        Self {
            agent,
            target,
            beta: -3.0,
            reward: RewardSpec::Absolute,
            episodes: 120,
            warmup_episodes: 10,
            opt_steps_per_episode: 20,
            eval_batches: 2,
            seed: 7,
            ddpg,
            log_every: 20,
        }
    }

    /// Paper-scale episode counts (310 quantization / 410 others) with the
    /// paper's exploration decay.
    pub fn paper(agent: AgentKind, target: f64) -> Self {
        let mut cfg = Self::new(agent, target);
        cfg.episodes = match agent {
            AgentKind::Quantization => 310,
            _ => 410,
        };
        cfg.ddpg.sigma_decay = 0.95;
        cfg
    }

    /// Quick configuration for tests and the micro variant.
    pub fn fast(agent: AgentKind, target: f64) -> Self {
        let mut cfg = Self::new(agent, target);
        cfg.episodes = 30;
        cfg.warmup_episodes = 5;
        cfg.opt_steps_per_episode = 10;
        cfg.eval_batches = 1;
        cfg
    }

    /// Load overrides from a JSON config object (configs/*.json): any
    /// subset of {target, beta, reward, reward_w, episodes,
    /// warmup_episodes, opt_steps_per_episode, eval_batches, seed,
    /// log_every} plus optional ddpg.{hidden, actor_lr, critic_lr, gamma,
    /// tau, batch, replay_capacity, sigma0, sigma_decay, reward_ema,
    /// grad_clip}.
    ///
    /// Unknown keys are an error (listing the valid ones), so a typo like
    /// `episdoes` fails loudly instead of silently running the defaults.
    ///
    /// Atomic: on any error the configuration is left untouched — a failed
    /// apply never leaves a half-applied hybrid behind.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let mut staged = self.clone();
        staged.apply_json_staged(j)?;
        *self = staged;
        Ok(())
    }

    /// The mutating half of `apply_json`, run against a staged clone so
    /// errors after early field writes cannot leak partial state.
    fn apply_json_staged(&mut self, j: &Json) -> Result<()> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config overrides must be a JSON object"))?;
        for key in obj.keys() {
            anyhow::ensure!(
                CONFIG_KEYS.contains(&key.as_str()),
                "unknown config key '{key}' (valid keys: {})",
                CONFIG_KEYS.join(", ")
            );
        }
        // a present key with the wrong type is as loud an error as an
        // unknown key — `"episodes": "55"` must not silently run defaults
        let f = |k: &str| -> Result<Option<f64>> {
            match j.get(k) {
                None => Ok(None),
                Some(v) => Ok(Some(v.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("config key '{k}' must be a number")
                })?)),
            }
        };
        if let Some(v) = f("target")? {
            self.target = v;
        }
        if let Some(v) = f("beta")? {
            self.beta = v;
        }
        if let Some(v) = j.get("reward") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("config key 'reward' must be a string"))?;
            self.reward = s.parse()?;
        }
        if let Some(w) = f("reward_w")? {
            anyhow::ensure!(
                matches!(self.reward, RewardSpec::HardExponential { .. }),
                "'reward_w' only applies to the hard_exponential reward"
            );
            self.reward = RewardSpec::HardExponential { w };
        }
        if let Some(v) = f("episodes")? {
            self.episodes = v as usize;
        }
        if let Some(v) = f("warmup_episodes")? {
            self.warmup_episodes = v as usize;
        }
        if let Some(v) = f("opt_steps_per_episode")? {
            self.opt_steps_per_episode = v as usize;
        }
        if let Some(v) = f("eval_batches")? {
            self.eval_batches = v as usize;
        }
        if let Some(v) = f("seed")? {
            self.seed = v as u64;
        }
        if let Some(v) = f("log_every")? {
            self.log_every = v as usize;
        }
        if let Some(d) = j.get("ddpg") {
            let dobj = d
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("config key 'ddpg' must be an object"))?;
            for key in dobj.keys() {
                anyhow::ensure!(
                    DDPG_KEYS.contains(&key.as_str()),
                    "unknown ddpg config key '{key}' (valid keys: {})",
                    DDPG_KEYS.join(", ")
                );
            }
            let g = |k: &str| -> Result<Option<f64>> {
                match d.get(k) {
                    None => Ok(None),
                    Some(v) => Ok(Some(v.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("ddpg config key '{k}' must be a number")
                    })?)),
                }
            };
            if let Some(h) = d.get("hidden") {
                let h = h
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("ddpg 'hidden' must be [h1, h2]"))?;
                anyhow::ensure!(h.len() == 2, "ddpg 'hidden' must be [h1, h2]");
                let h1 = h[0]
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("ddpg 'hidden' holds a non-number"))?;
                let h2 = h[1]
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("ddpg 'hidden' holds a non-number"))?;
                self.ddpg.hidden = (h1, h2);
            }
            if let Some(v) = g("actor_lr")? {
                self.ddpg.actor_lr = v as f32;
            }
            if let Some(v) = g("critic_lr")? {
                self.ddpg.critic_lr = v as f32;
            }
            if let Some(v) = g("sigma0")? {
                self.ddpg.sigma0 = v;
            }
            if let Some(v) = g("sigma_decay")? {
                self.ddpg.sigma_decay = v;
            }
            if let Some(v) = g("batch")? {
                self.ddpg.batch = v as usize;
            }
            if let Some(v) = g("replay_capacity")? {
                self.ddpg.replay_capacity = v as usize;
            }
            if let Some(v) = g("gamma")? {
                self.ddpg.gamma = v as f32;
            }
            if let Some(v) = g("tau")? {
                self.ddpg.tau = v as f32;
            }
            if let Some(v) = g("reward_ema")? {
                self.ddpg.reward_ema = v;
            }
            if let Some(v) = g("grad_clip")? {
                self.ddpg.grad_clip = v as f32;
            }
        }
        Ok(())
    }

    /// JSON form (the `config` block of a result record).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("agent", Json::str(self.agent.to_string())),
            ("target", Json::num(self.target)),
            ("beta", Json::num(self.beta)),
            ("reward", Json::str(self.reward.to_string())),
            ("episodes", Json::num(self.episodes as f64)),
            ("warmup_episodes", Json::num(self.warmup_episodes as f64)),
            ("opt_steps_per_episode", Json::num(self.opt_steps_per_episode as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    /// Full, loss-free serialization for driver checkpoints: every field
    /// including the DDPG block, the reward spec's shape parameters, and
    /// the exact u64 seed (hex — large sweep-job seeds do not survive the
    /// f64 number path `to_json` uses for display).
    pub fn to_checkpoint_json(&self) -> Json {
        Json::obj(vec![
            ("agent", Json::str(self.agent.to_string())),
            ("target", Json::num(self.target)),
            ("beta", Json::num(self.beta)),
            ("reward", self.reward.to_json()),
            ("episodes", Json::num(self.episodes as f64)),
            ("warmup_episodes", Json::num(self.warmup_episodes as f64)),
            ("opt_steps_per_episode", Json::num(self.opt_steps_per_episode as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("seed", Json::hex64(self.seed)),
            ("log_every", Json::num(self.log_every as f64)),
            ("ddpg", self.ddpg.to_json()),
        ])
    }

    /// Rebuild a configuration serialized by
    /// [`SearchConfig::to_checkpoint_json`].
    pub fn from_checkpoint_json(j: &Json) -> Result<Self> {
        Ok(Self {
            agent: j.req_str("agent")?.parse()?,
            target: j.req_f64("target")?,
            beta: j.req_f64("beta")?,
            reward: RewardSpec::from_json(j.req("reward")?)?,
            episodes: j.req_usize("episodes")?,
            warmup_episodes: j.req_usize("warmup_episodes")?,
            opt_steps_per_episode: j.req_usize("opt_steps_per_episode")?,
            eval_batches: j.req_usize("eval_batches")?,
            seed: j.req_hex64("seed")?,
            log_every: j.req_usize("log_every")?,
            ddpg: DdpgConfig::from_json(j.req("ddpg")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_episode_counts() {
        assert_eq!(SearchConfig::paper(AgentKind::Quantization, 0.3).episodes, 310);
        assert_eq!(SearchConfig::paper(AgentKind::Pruning, 0.3).episodes, 410);
        assert_eq!(SearchConfig::paper(AgentKind::Joint, 0.3).episodes, 410);
    }

    #[test]
    fn apply_json_overrides() {
        let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
        let j = Json::parse(
            r#"{"episodes": 55, "beta": -6.0, "log_every": 0, "ddpg": {"sigma0": 0.7, "batch": 64, "hidden": [48, 32]}}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.episodes, 55);
        assert_eq!(cfg.beta, -6.0);
        assert_eq!(cfg.log_every, 0);
        assert_eq!(cfg.ddpg.sigma0, 0.7);
        assert_eq!(cfg.ddpg.batch, 64);
        assert_eq!(cfg.ddpg.hidden, (48, 32));
        // untouched fields keep defaults
        assert_eq!(cfg.warmup_episodes, 10);
    }

    #[test]
    fn apply_json_rejects_unknown_keys() {
        let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
        // the classic typo: silently ignored before, a loud error now
        let err = cfg
            .apply_json(&Json::parse(r#"{"episdoes": 55}"#).unwrap())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("episdoes"), "{msg}");
        assert!(msg.contains("episodes"), "error must list the valid keys: {msg}");
        // unknown nested ddpg keys fail too
        let err = cfg
            .apply_json(&Json::parse(r#"{"ddpg": {"sgima0": 0.7}}"#).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("sgima0"));
        // non-object configs fail
        assert!(cfg.apply_json(&Json::parse("[1]").unwrap()).is_err());
        // wrong-typed values for valid keys fail just as loudly
        let err = cfg
            .apply_json(&Json::parse(r#"{"episodes": "55"}"#).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("must be a number"), "{err:#}");
        assert!(cfg
            .apply_json(&Json::parse(r#"{"ddpg": {"batch": true}}"#).unwrap())
            .is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"reward": 3}"#).unwrap()).is_err());
        // a failed apply must not have touched the config, even when the
        // error surfaces after valid fields (atomic staging)
        let err = cfg
            .apply_json(&Json::parse(r#"{"episodes": 55, "ddpg": {"bad": 1}}"#).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("bad"));
        assert_eq!(cfg.episodes, 120, "partial apply leaked");
        assert_eq!(cfg.ddpg.sigma0, 0.5);
    }

    #[test]
    fn apply_json_reward_selection() {
        let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
        cfg.apply_json(&Json::parse(r#"{"reward": "hard_exponential", "reward_w": -4.0}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.reward, crate::reward::RewardSpec::HardExponential { w: -4.0 });
        // reward_w without the hard_exponential family is an error
        let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
        assert!(cfg.apply_json(&Json::parse(r#"{"reward_w": -4.0}"#).unwrap()).is_err());
    }

    #[test]
    fn repo_config_files_parse() {
        for name in ["configs/paper.json", "configs/default.json"] {
            let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
            if path.exists() {
                let j = Json::read_file(&path).unwrap();
                let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
                cfg.apply_json(&j).unwrap();
                assert!(cfg.episodes > 0);
            }
        }
    }

    #[test]
    fn json_has_fields() {
        let j = SearchConfig::new(AgentKind::Joint, 0.2).to_json();
        assert_eq!(j.req_str("agent").unwrap(), "joint");
        assert_eq!(j.req_f64("target").unwrap(), 0.2);
        assert_eq!(j.req_f64("beta").unwrap(), -3.0);
        assert_eq!(j.req_str("reward").unwrap(), "absolute");
    }

    #[test]
    fn checkpoint_json_roundtrips_every_field() {
        let mut cfg = SearchConfig::fast(AgentKind::Quantization, 0.37);
        cfg.seed = 0xfeed_f00d_dead_beef; // > 2^53: must survive via hex
        cfg.log_every = 3;
        cfg.reward = crate::reward::RewardSpec::HardExponential { w: -2.5 };
        cfg.ddpg.hidden = (48, 32);
        cfg.ddpg.sigma_decay = 0.9125;
        let back = SearchConfig::from_checkpoint_json(
            &Json::parse(&cfg.to_checkpoint_json().dump()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.agent, cfg.agent);
        assert_eq!(back.target, cfg.target);
        assert_eq!(back.beta, cfg.beta);
        assert_eq!(back.reward, cfg.reward);
        assert_eq!(back.episodes, cfg.episodes);
        assert_eq!(back.warmup_episodes, cfg.warmup_episodes);
        assert_eq!(back.opt_steps_per_episode, cfg.opt_steps_per_episode);
        assert_eq!(back.eval_batches, cfg.eval_batches);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.log_every, cfg.log_every);
        assert_eq!(back.ddpg.hidden, cfg.ddpg.hidden);
        assert_eq!(back.ddpg.sigma_decay.to_bits(), cfg.ddpg.sigma_decay.to_bits());
        assert_eq!(back.ddpg.batch, cfg.ddpg.batch);
    }
}
