//! Search configuration (paper defaults + CPU-budget scaling).

use crate::agent::{AgentKind, DdpgConfig};
use crate::util::json::Json;

/// Hyper-parameters of one policy search.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Which agent runs the search.
    pub agent: AgentKind,
    /// Target compression rate c (fraction of the original latency).
    pub target: f64,
    /// Reward cost exponent beta (paper: -3.0).
    pub beta: f64,
    /// Total episodes (paper: 310 quantization, 410 pruning/joint).
    pub episodes: usize,
    /// Random warm-up episodes filling the replay buffer (paper: 10).
    pub warmup_episodes: usize,
    /// Agent optimization steps per post-warmup episode.
    pub opt_steps_per_episode: usize,
    /// Validation batches per accuracy evaluation.
    pub eval_batches: usize,
    /// RNG seed (forked per subsystem).
    pub seed: u64,
    /// DDPG agent hyper-parameters.
    pub ddpg: DdpgConfig,
    /// Log a progress line every N episodes (0 = silent).
    pub log_every: usize,
}

impl SearchConfig {
    /// CPU-budget defaults: 120 episodes with a rescaled exploration decay.
    pub fn new(agent: AgentKind, target: f64) -> Self {
        let mut ddpg = DdpgConfig::default();
        // The paper's sigma decay (0.95/episode) is tuned for 310-410
        // episodes; at this CPU-budget default of 120 episodes it would
        // collapse exploration by ep ~40 and strand the agent in early
        // local optima.  Scale the decay so sigma ends near 0.02.
        ddpg.sigma_decay = 0.975;
        Self {
            agent,
            target,
            beta: -3.0,
            episodes: 120,
            warmup_episodes: 10,
            opt_steps_per_episode: 20,
            eval_batches: 2,
            seed: 7,
            ddpg,
            log_every: 20,
        }
    }

    /// Paper-scale episode counts (310 quantization / 410 others) with the
    /// paper's exploration decay.
    pub fn paper(agent: AgentKind, target: f64) -> Self {
        let mut cfg = Self::new(agent, target);
        cfg.episodes = match agent {
            AgentKind::Quantization => 310,
            _ => 410,
        };
        cfg.ddpg.sigma_decay = 0.95;
        cfg
    }

    /// Quick configuration for tests and the micro variant.
    pub fn fast(agent: AgentKind, target: f64) -> Self {
        let mut cfg = Self::new(agent, target);
        cfg.episodes = 30;
        cfg.warmup_episodes = 5;
        cfg.opt_steps_per_episode = 10;
        cfg.eval_batches = 1;
        cfg
    }

    /// Load overrides from a JSON config file (configs/*.json): any subset
    /// of {target, beta, episodes, warmup_episodes, opt_steps_per_episode,
    /// eval_batches, seed} plus optional ddpg.{sigma0, sigma_decay, batch,
    /// replay_capacity, gamma, tau}.
    pub fn apply_json(&mut self, j: &Json) {
        let f = |k: &str| j.get(k).and_then(Json::as_f64);
        if let Some(v) = f("target") {
            self.target = v;
        }
        if let Some(v) = f("beta") {
            self.beta = v;
        }
        if let Some(v) = f("episodes") {
            self.episodes = v as usize;
        }
        if let Some(v) = f("warmup_episodes") {
            self.warmup_episodes = v as usize;
        }
        if let Some(v) = f("opt_steps_per_episode") {
            self.opt_steps_per_episode = v as usize;
        }
        if let Some(v) = f("eval_batches") {
            self.eval_batches = v as usize;
        }
        if let Some(v) = f("seed") {
            self.seed = v as u64;
        }
        if let Some(d) = j.get("ddpg") {
            let g = |k: &str| d.get(k).and_then(Json::as_f64);
            if let Some(v) = g("sigma0") {
                self.ddpg.sigma0 = v;
            }
            if let Some(v) = g("sigma_decay") {
                self.ddpg.sigma_decay = v;
            }
            if let Some(v) = g("batch") {
                self.ddpg.batch = v as usize;
            }
            if let Some(v) = g("replay_capacity") {
                self.ddpg.replay_capacity = v as usize;
            }
            if let Some(v) = g("gamma") {
                self.ddpg.gamma = v as f32;
            }
            if let Some(v) = g("tau") {
                self.ddpg.tau = v as f32;
            }
        }
    }

    /// JSON form (the `config` block of a result record).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("agent", Json::str(self.agent.label())),
            ("target", Json::num(self.target)),
            ("beta", Json::num(self.beta)),
            ("episodes", Json::num(self.episodes as f64)),
            ("warmup_episodes", Json::num(self.warmup_episodes as f64)),
            ("opt_steps_per_episode", Json::num(self.opt_steps_per_episode as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_episode_counts() {
        assert_eq!(SearchConfig::paper(AgentKind::Quantization, 0.3).episodes, 310);
        assert_eq!(SearchConfig::paper(AgentKind::Pruning, 0.3).episodes, 410);
        assert_eq!(SearchConfig::paper(AgentKind::Joint, 0.3).episodes, 410);
    }

    #[test]
    fn apply_json_overrides() {
        let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
        let j = Json::parse(
            r#"{"episodes": 55, "beta": -6.0, "ddpg": {"sigma0": 0.7, "batch": 64}}"#,
        )
        .unwrap();
        cfg.apply_json(&j);
        assert_eq!(cfg.episodes, 55);
        assert_eq!(cfg.beta, -6.0);
        assert_eq!(cfg.ddpg.sigma0, 0.7);
        assert_eq!(cfg.ddpg.batch, 64);
        // untouched fields keep defaults
        assert_eq!(cfg.warmup_episodes, 10);
    }

    #[test]
    fn repo_config_files_parse() {
        for name in ["configs/paper.json", "configs/default.json"] {
            let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
            if path.exists() {
                let j = Json::read_file(&path).unwrap();
                let mut cfg = SearchConfig::new(AgentKind::Joint, 0.3);
                cfg.apply_json(&j);
                assert!(cfg.episodes > 0);
            }
        }
    }

    #[test]
    fn json_has_fields() {
        let j = SearchConfig::new(AgentKind::Joint, 0.2).to_json();
        assert_eq!(j.req_str("agent").unwrap(), "joint");
        assert_eq!(j.req_f64("target").unwrap(), 0.2);
        assert_eq!(j.req_f64("beta").unwrap(), -3.0);
    }
}
