//! Observability: process-wide metrics registry, Chrome-trace span
//! tracing, and schema-versioned snapshots.
//!
//! Three pieces, wired through every hot layer (driver, profiler, latency
//! caches, serve service, sweep orchestrator):
//!
//! * `metrics` — labeled counters / gauges / fixed-bucket histograms
//!   behind a global registry; registration is the cold path, recording
//!   is relaxed atomics on sharded cells.  ON by default, gated
//!   process-wide by `metrics::set_enabled`.
//! * `trace` — RAII spans emitted as Chrome trace-event JSON
//!   (Perfetto-loadable), opt-in via `GALEN_TRACE`; a single relaxed
//!   atomic load when disabled.
//! * `snapshot` — `MetricsSnapshot`: the schema-versioned JSON form that
//!   crosses process boundaries (the `metrics` serve verb,
//!   `galen report --metrics`).
//!
//! The subsystem-wide invariant is **inertness**: nothing here feeds back
//! into computed values or RNG streams, so searches are bit-identical
//! with observability on or off (`tests/obs_inertness.rs`) and the
//! hot-path overhead stays under the 2% budget
//! (`search/obs_overhead` in `benches/hot_paths.rs`).

pub mod metrics;
pub mod snapshot;
pub mod trace;

pub use metrics::{latency_bounds, Counter, Gauge, Histogram};
pub use snapshot::{HistogramSnapshot, MetricsSnapshot, METRICS_SCHEMA_VERSION};
