//! Point-in-time metrics snapshots: schema-versioned JSON round-trip and
//! a fixed-width human table.
//!
//! A snapshot is what crosses process boundaries — the `metrics` serve
//! verb returns one, `GALEN_TRACE` sessions write one next to the trace
//! file, and `galen report --metrics` parses one back (`from_json`, which
//! validates the schema version) to render the table.  Keys are the
//! registry's canonical `name{label="value"}` strings in `BTreeMap`
//! order, so two snapshots of the same state serialize identically.
//!
//! Counter values travel as JSON numbers; they are exact up to 2^53,
//! far beyond any realistic event count, and `from_json(to_json(s)) == s`
//! is asserted in tests.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::json::Json;

use super::metrics::{self, Instrument};

/// Bump when the snapshot JSON layout changes; `from_json` rejects
/// mismatched documents instead of mis-parsing them.
pub const METRICS_SCHEMA_VERSION: usize = 1;

/// Frozen state of one histogram.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistogramSnapshot {
    /// Ascending bucket upper bounds (overflow bucket implicit).
    pub bounds: Vec<f64>,
    /// Per-bucket counts (`bounds.len() + 1` cells, overflow last).
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of observed values.
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the `q`-quantile observation
    /// (`q` in [0, 1]); infinity when it landed in the overflow bucket,
    /// 0 when empty.  A bucketed bound, not an interpolation — exact
    /// enough for a glanceable table.
    pub fn quantile_upper_bound(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }
}

/// Frozen state of the whole registry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter totals by canonical key.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by canonical key.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram states by canonical key.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// Freeze the current state of every registered instrument.
    pub fn capture() -> Self {
        let mut snap = Self::default();
        metrics::visit(|key, inst| match inst {
            Instrument::Counter(c) => {
                snap.counters.insert(key.to_string(), c.value());
            }
            Instrument::Gauge(g) => {
                snap.gauges.insert(key.to_string(), g.value());
            }
            Instrument::Histogram(h) => {
                snap.histograms.insert(
                    key.to_string(),
                    HistogramSnapshot {
                        bounds: h.bounds().to_vec(),
                        buckets: h.bucket_counts(),
                        count: h.count(),
                        sum: h.sum(),
                    },
                );
            }
        });
        snap
    }

    /// Convenience lookup for tests and assertions.
    pub fn counter(&self, key: &str) -> Option<u64> {
        self.counters.get(key).copied()
    }

    /// Serialize (schema-versioned; deterministic key order).
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("bounds", Json::arr_f64(&h.bounds)),
                        (
                            "buckets",
                            Json::arr_usize(
                                &h.buckets.iter().map(|&n| n as usize).collect::<Vec<_>>(),
                            ),
                        ),
                        ("count", Json::num(h.count as f64)),
                        ("sum", Json::num(h.sum)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("schema_version", Json::num(METRICS_SCHEMA_VERSION as f64)),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }

    /// Parse a snapshot back, rejecting unknown schema versions.
    pub fn from_json(j: &Json) -> Result<Self> {
        let version = j.req_usize("schema_version")?;
        anyhow::ensure!(
            version == METRICS_SCHEMA_VERSION,
            "metrics snapshot schema v{version} (this build reads v{METRICS_SCHEMA_VERSION})"
        );
        let mut snap = Self::default();
        let section = |key: &str| -> Result<&BTreeMap<String, Json>> {
            j.req(key)?
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("'{key}' is not an object"))
        };
        for (k, v) in section("counters")? {
            let v = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("counter '{k}' is not a number"))?;
            snap.counters.insert(k.clone(), v as u64);
        }
        for (k, v) in section("gauges")? {
            let v = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("gauge '{k}' is not a number"))?;
            snap.gauges.insert(k.clone(), v);
        }
        for (k, h) in section("histograms")? {
            let bounds = h.req_f64s("bounds")?;
            let buckets: Vec<u64> = h
                .req_arr("buckets")?
                .iter()
                .map(|b| {
                    b.as_usize()
                        .map(|n| n as u64)
                        .ok_or_else(|| anyhow::anyhow!("histogram '{k}': bad bucket count"))
                })
                .collect::<Result<_>>()?;
            anyhow::ensure!(
                buckets.len() == bounds.len() + 1,
                "histogram '{k}': {} buckets for {} bounds",
                buckets.len(),
                bounds.len()
            );
            snap.histograms.insert(
                k.clone(),
                HistogramSnapshot {
                    bounds,
                    buckets,
                    count: h.req_usize("count")? as u64,
                    sum: h.req_f64("sum")?,
                },
            );
        }
        Ok(snap)
    }

    /// Render as a fixed-width human table (what `galen report --metrics`
    /// prints): counters, gauges, then histograms with count / mean /
    /// bucketed p50 / p95.
    pub fn table(&self) -> String {
        let mut out = format!(
            "metrics snapshot (schema v{METRICS_SCHEMA_VERSION}): {} counters, {} gauges, {} histograms\n",
            self.counters.len(),
            self.gauges.len(),
            self.histograms.len()
        );
        if !self.counters.is_empty() {
            out.push_str("\ncounters\n");
            for (k, v) in &self.counters {
                out.push_str(&format!("  {k:<56} {v:>14}\n"));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("\ngauges\n");
            for (k, v) in &self.gauges {
                out.push_str(&format!("  {k:<56} {v:>14.6}\n"));
            }
        }
        if !self.histograms.is_empty() {
            out.push_str("\nhistograms\n");
            for (k, h) in &self.histograms {
                out.push_str(&format!(
                    "  {k:<56} count={:<8} mean={:<12.3e} p50<={:<12.3e} p95<={:.3e}\n",
                    h.count,
                    h.mean(),
                    h.quantile_upper_bound(0.5),
                    h.quantile_upper_bound(0.95),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::{Counter, Gauge, Histogram};

    #[test]
    fn snapshot_roundtrips_through_json() {
        // unique names: the registry is process-global and tests share it
        let c = Counter::register("test_obs_snap_total", &[("kind", "roundtrip")]);
        c.add(42);
        let g = Gauge::register("test_obs_snap_gauge", &[]);
        g.set(1.25);
        let h = Histogram::register("test_obs_snap_seconds", &[], &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(5.0);

        let snap = MetricsSnapshot::capture();
        assert_eq!(snap.counter("test_obs_snap_total{kind=\"roundtrip\"}"), Some(42));
        let text = snap.to_json().dump();
        let back = MetricsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap, "snapshot must round-trip bit-exactly");

        // wrong schema version is rejected, not mis-parsed
        let wrong = text.replacen("\"schema_version\":1", "\"schema_version\":99", 1);
        assert!(MetricsSnapshot::from_json(&Json::parse(&wrong).unwrap()).is_err());
    }

    #[test]
    fn histogram_snapshot_moments() {
        let h = HistogramSnapshot {
            bounds: vec![1.0, 2.0, 4.0],
            buckets: vec![5, 3, 1, 1],
            count: 10,
            sum: 15.0,
        };
        assert_eq!(h.mean(), 1.5);
        assert_eq!(h.quantile_upper_bound(0.5), 1.0);
        assert_eq!(h.quantile_upper_bound(0.9), 4.0);
        assert_eq!(h.quantile_upper_bound(1.0), f64::INFINITY);
        assert_eq!(HistogramSnapshot::default().quantile_upper_bound(0.5), 0.0);
        assert_eq!(HistogramSnapshot::default().mean(), 0.0);
    }

    #[test]
    fn table_renders_every_section() {
        let c = Counter::register("test_obs_table_total", &[]);
        c.inc();
        let g = Gauge::register("test_obs_table_gauge", &[]);
        g.set(3.0);
        let h = Histogram::register("test_obs_table_seconds", &[], &[1.0]);
        h.observe(0.5);
        let table = MetricsSnapshot::capture().table();
        for needle in [
            "counters",
            "gauges",
            "histograms",
            "test_obs_table_total",
            "test_obs_table_gauge",
            "test_obs_table_seconds",
        ] {
            assert!(table.contains(needle), "missing '{needle}' in:\n{table}");
        }
    }
}
