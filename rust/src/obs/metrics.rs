//! Process-wide metrics registry: labeled counters, gauges, and
//! fixed-bucket histograms behind lock-free hot-path handles.
//!
//! Design invariants (see ARCHITECTURE.md §Observability):
//!
//! * **Registration is the cold path, recording is the hot path.**  A
//!   handle is obtained once (one mutex-guarded map lookup keyed by the
//!   canonical `name{label="value"}` string) and then recorded through
//!   with nothing but relaxed atomics — counters shard their cells across
//!   cache-line-padded slots indexed by a per-thread id so concurrent
//!   workers never contend on one line, gauges store `f64` bits in a
//!   single atomic, histograms bucket into a fixed, deterministic layout
//!   chosen at registration.
//! * **One global enable gate.**  `set_enabled(false)` turns every
//!   `inc`/`set`/`observe` into a single relaxed load + branch; handles
//!   stay valid and registration still works, so instrumented code never
//!   needs its own conditionals.  Metrics are ON by default — recording
//!   is cheap enough to leave running (budget asserted by the
//!   `search/obs_overhead` hot-paths section, < 2%).
//! * **Observability is inert.**  Nothing in this module feeds back into
//!   computed values or RNG streams; instrumented code produces
//!   bit-identical results with metrics on or off (asserted per-agent in
//!   `tests/obs_inertness.rs`).
//!
//! The registry is process-global so independent subsystems (driver,
//! profiler, serve workers) aggregate into one snapshot; per-instance
//! counters such as `ProfilerStats` remain the exact per-object views the
//! tests assert on, while the registry carries the process-wide totals
//! surfaced by the `metrics` serve verb and `galen report --metrics`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::sync::lock;

/// Counter shard count: enough slots that a sweep's worker threads land
/// on distinct cache lines with high probability, small enough that
/// summing a snapshot stays trivial.  Must be a power of two.
const SHARDS: usize = 16;

/// Global recording gate (ON by default).  Gates *recording* only:
/// registration, handle cloning, and snapshot reads always work.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable or disable metric recording process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether metric recording is currently enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Small dense process-unique id of the calling thread (0, 1, 2, ... in
/// first-use order).  Shared by the counter shard selector and the trace
/// writer's `tid` field so a thread's spans and its metric activity
/// correlate.
pub fn thread_id() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static ID: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ID.with(|id| *id)
}

/// One cache line per shard so concurrent `fetch_add`s from different
/// threads do not false-share.
#[derive(Debug)]
#[repr(align(64))]
struct Shard(AtomicU64);

#[derive(Debug)]
struct CounterInner {
    shards: [Shard; SHARDS],
}

/// Monotonic event counter.  Cloning shares the underlying cells.
#[derive(Clone, Debug)]
pub struct Counter(Arc<CounterInner>);

impl Counter {
    /// Obtain (registering on first use) the counter `name` with `labels`.
    /// Panics if the same full key is already registered as a different
    /// instrument type — that is a programming error, not a runtime
    /// condition.
    pub fn register(name: &str, labels: &[(&str, &str)]) -> Counter {
        registry().counter(&full_key(name, labels))
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (relaxed fetch-add on this thread's shard; no-op while
    /// recording is disabled).
    pub fn add(&self, n: u64) {
        if !enabled() {
            return;
        }
        self.0.shards[thread_id() & (SHARDS - 1)]
            .0
            .fetch_add(n, Ordering::Relaxed);
    }

    /// Current total across all shards.
    pub fn value(&self) -> u64 {
        self.0
            .shards
            .iter()
            .map(|s| s.0.load(Ordering::Relaxed))
            .sum()
    }
}

/// Last-write-wins instantaneous value (stored as `f64` bits in one
/// atomic).  Cloning shares the cell.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Obtain (registering on first use) the gauge `name` with `labels`.
    /// Panics on an instrument-type conflict, like `Counter::register`.
    pub fn register(name: &str, labels: &[(&str, &str)]) -> Gauge {
        registry().gauge(&full_key(name, labels))
    }

    /// Set the value (no-op while recording is disabled).
    pub fn set(&self, v: f64) {
        if !enabled() {
            return;
        }
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Add `d` to the value (lock-free compare-exchange loop; no-op while
    /// recording is disabled).
    pub fn add(&self, d: f64) {
        if !enabled() {
            return;
        }
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistogramInner {
    /// Ascending bucket upper bounds; an implicit overflow bucket catches
    /// everything above the last bound.
    bounds: Vec<f64>,
    /// `bounds.len() + 1` cells: `buckets[i]` counts observations
    /// `<= bounds[i]`, the final cell counts the overflow.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Running sum as `f64` bits, accumulated by compare-exchange.
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram with a deterministic layout chosen at
/// registration.  Cloning shares the cells.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    /// Obtain (registering on first use) the histogram `name` with
    /// `labels` and ascending `bounds`.  Panics on an instrument-type
    /// conflict or when re-registering the same key with different bounds
    /// — bucket layouts are part of the metric's identity.
    pub fn register(name: &str, labels: &[(&str, &str)], bounds: &[f64]) -> Histogram {
        registry().histogram(&full_key(name, labels), bounds)
    }

    /// Record one observation (two relaxed fetch-adds + one
    /// compare-exchange loop; no-op while recording is disabled).
    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        let i = self.0.bounds.partition_point(|b| v > *b);
        self.0.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.0.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Record a wall-clock duration in seconds.
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// The bucket upper bounds (ascending; overflow bucket implicit).
    pub fn bounds(&self) -> &[f64] {
        &self.0.bounds
    }

    /// Per-bucket counts (`bounds().len() + 1` cells, overflow last).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.0
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }
}

/// The standard latency bucket layout: powers of two from 1 microsecond
/// to ~8.4 seconds (24 buckets + overflow).  Deterministic — every
/// process, every run, the same edges — so snapshots from different
/// sessions are directly comparable.
pub fn latency_bounds() -> Vec<f64> {
    let mut bounds = Vec::with_capacity(24);
    let mut edge = 1e-6;
    for _ in 0..24 {
        bounds.push(edge);
        edge *= 2.0;
    }
    bounds
}

/// A registered instrument (snapshot visitor's view).
#[derive(Clone, Debug)]
pub(crate) enum Instrument {
    /// Monotonic counter.
    Counter(Counter),
    /// Instantaneous gauge.
    Gauge(Gauge),
    /// Fixed-bucket histogram.
    Histogram(Histogram),
}

struct Registry {
    map: Mutex<BTreeMap<String, Instrument>>,
}

fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(|| Registry {
        map: Mutex::new(BTreeMap::new()),
    })
}

impl Registry {
    fn counter(&self, key: &str) -> Counter {
        let mut map = lock(&self.map);
        match map.get(key) {
            Some(Instrument::Counter(c)) => c.clone(),
            Some(_) => panic!("metric '{key}' is already registered as a non-counter"),
            None => {
                let c = Counter(Arc::new(CounterInner {
                    shards: std::array::from_fn(|_| Shard(AtomicU64::new(0))),
                }));
                map.insert(key.to_string(), Instrument::Counter(c.clone()));
                c
            }
        }
    }

    fn gauge(&self, key: &str) -> Gauge {
        let mut map = lock(&self.map);
        match map.get(key) {
            Some(Instrument::Gauge(g)) => g.clone(),
            Some(_) => panic!("metric '{key}' is already registered as a non-gauge"),
            None => {
                let g = Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits())));
                map.insert(key.to_string(), Instrument::Gauge(g.clone()));
                g
            }
        }
    }

    fn histogram(&self, key: &str, bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && !bounds.is_empty(),
            "histogram '{key}': bounds must be non-empty and strictly ascending"
        );
        let mut map = lock(&self.map);
        match map.get(key) {
            Some(Instrument::Histogram(h)) => {
                assert_eq!(
                    h.bounds(),
                    bounds,
                    "metric '{key}' re-registered with different bucket bounds"
                );
                h.clone()
            }
            Some(_) => panic!("metric '{key}' is already registered as a non-histogram"),
            None => {
                let h = Histogram(Arc::new(HistogramInner {
                    bounds: bounds.to_vec(),
                    buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
                    count: AtomicU64::new(0),
                    sum_bits: AtomicU64::new(0.0f64.to_bits()),
                }));
                map.insert(key.to_string(), Instrument::Histogram(h.clone()));
                h
            }
        }
    }
}

/// Canonical full key: `name` alone without labels, otherwise
/// `name{k1="v1",k2="v2"}` with the label pairs sorted by key — the same
/// labels in any order address the same instrument, and `BTreeMap`
/// ordering makes every snapshot deterministic.
pub(crate) fn full_key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut pairs: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect();
    pairs.sort();
    format!("{name}{{{}}}", pairs.join(","))
}

/// Visit every registered instrument in key order (snapshot capture).
/// Holds the registry lock for the duration of the walk; callers must
/// not register from inside `f`.
pub(crate) fn visit(mut f: impl FnMut(&str, &Instrument)) {
    let map = lock(&registry().map);
    for (key, inst) in map.iter() {
        f(key, inst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_threads() {
        let c = Counter::register("test_obs_counter_threads_total", &[]);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = c.clone();
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 4000);
        // the handle is shared: re-registering addresses the same cells
        assert_eq!(
            Counter::register("test_obs_counter_threads_total", &[]).value(),
            4000
        );
    }

    #[test]
    fn labels_address_distinct_series_in_any_order() {
        let a = Counter::register("test_obs_labeled_total", &[("cache", "sim"), ("x", "1")]);
        let b = Counter::register("test_obs_labeled_total", &[("x", "1"), ("cache", "sim")]);
        let other = Counter::register("test_obs_labeled_total", &[("cache", "profile"), ("x", "1")]);
        a.add(3);
        assert_eq!(b.value(), 3, "label order must not split the series");
        assert_eq!(other.value(), 0);
        assert_eq!(
            full_key("m", &[("b", "2"), ("a", "1")]),
            "m{a=\"1\",b=\"2\"}"
        );
        assert_eq!(full_key("m", &[]), "m");
    }

    #[test]
    fn gauge_set_and_add() {
        let g = Gauge::register("test_obs_gauge", &[]);
        g.set(2.5);
        assert_eq!(g.value(), 2.5);
        g.add(-1.0);
        assert_eq!(g.value(), 1.5);
    }

    #[test]
    fn histogram_buckets_and_moments() {
        let h = Histogram::register("test_obs_hist_seconds", &[], &[1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 106.0);
        // <=1.0 catches 0.5 and the exactly-on-edge 1.0; overflow catches 100
        assert_eq!(h.bucket_counts(), vec![2, 1, 1, 1]);
        assert_eq!(h.bounds(), &[1.0, 2.0, 4.0]);
    }

    #[test]
    fn latency_bounds_are_deterministic_and_ascending() {
        let b = latency_bounds();
        assert_eq!(b, latency_bounds());
        assert_eq!(b.len(), 24);
        assert_eq!(b[0], 1e-6);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        assert!(b[23] > 8.0 && b[23] < 9.0);
    }

    // NOTE: the enable-gate semantics are asserted in
    // tests/obs_inertness.rs, which runs in its own process — toggling the
    // process-global gate here would race the exact-count assertions of
    // sibling unit tests running in parallel.

    #[test]
    fn thread_ids_are_small_and_stable() {
        let here = thread_id();
        assert_eq!(here, thread_id(), "stable within a thread");
        let other = std::thread::spawn(thread_id).join().unwrap();
        assert_ne!(here, other);
    }
}
