//! Hierarchical span tracing emitted as Chrome trace-event JSON.
//!
//! Spans are RAII guards: `trace::span("episode")` starts one, dropping
//! it records a complete ("ph":"X") event with microsecond `ts`/`dur`
//! relative to trace start, `pid` 0, and `tid` set to the process-unique
//! thread id shared with the metrics shard selector — so the resulting
//! `trace_<session>.json` loads directly in Perfetto / `chrome://tracing`
//! with one lane per worker thread, and nesting falls out of the
//! `ts`/`dur` containment of spans opened within spans.
//!
//! Tracing is opt-in via `GALEN_TRACE` and **off by default**: when
//! disabled, `span()` is a single relaxed atomic load returning an inert
//! guard, so the hot path costs ~nothing (part of the
//! `search/obs_overhead` budget).  When enabled, finished spans buffer in
//! memory and `flush()` writes the whole document — tracing never does
//! I/O inside instrumented code.
//!
//! Like the metrics registry, tracing is provably inert: it reads
//! wall-clock time and already-computed labels, never an RNG stream or a
//! value that feeds back into the search (`tests/obs_inertness.rs`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::sync::lock;

use super::metrics::thread_id;

static ENABLED: AtomicBool = AtomicBool::new(false);

struct TraceBuf {
    path: PathBuf,
    start: Instant,
    events: Vec<Json>,
}

static BUF: Mutex<Option<TraceBuf>> = Mutex::new(None);

/// Whether span recording is active (one relaxed load — this is the
/// entire disabled-path cost of `span()`).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording spans, to be written to `path` by `flush()`.  Replaces
/// any previous trace buffer (its unflushed events are dropped).
pub fn enable_to(path: &Path) {
    *lock(&BUF) = Some(TraceBuf {
        path: path.to_path_buf(),
        start: Instant::now(),
        events: Vec::new(),
    });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording and drop any unflushed events.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    *lock(&BUF) = None;
}

/// Honor `GALEN_TRACE`: when set to anything but ``/`0`/`false`/`off`,
/// enable tracing to `<results dir>/trace_<session>.json` and return that
/// path.  The CLI calls this once per invocation with the command name as
/// the session label.
pub fn init_from_env(session: &str) -> Option<PathBuf> {
    let v = std::env::var("GALEN_TRACE").ok()?;
    if matches!(v.as_str(), "" | "0" | "false" | "off") {
        return None;
    }
    let path = crate::results_dir().join(format!("trace_{session}.json"));
    enable_to(&path);
    Some(path)
}

/// Write everything recorded so far as a Chrome trace-event document
/// (`{"traceEvents": [...]}`) to the path given at `enable_to`.  Returns
/// the path written, or `None` when tracing was never enabled.  Keeps the
/// buffer, so later flushes rewrite the file with a superset of events —
/// call it on every exit path; crashing between flushes only loses spans
/// since the last one.
pub fn flush() -> Result<Option<PathBuf>> {
    let guard = lock(&BUF);
    let Some(buf) = guard.as_ref() else {
        return Ok(None);
    };
    let doc = Json::obj(vec![
        ("traceEvents", Json::Arr(buf.events.clone())),
        ("displayTimeUnit", Json::str("ms")),
    ]);
    doc.write_file(&buf.path)?;
    Ok(Some(buf.path.clone()))
}

/// RAII span guard: records a complete event on drop.  Inert (a `None`)
/// when tracing is disabled at creation.
pub struct Span(Option<SpanData>);

struct SpanData {
    name: String,
    start: Instant,
    args: Vec<(&'static str, String)>,
}

/// Open a span named `name`; the span covers until the guard drops.
pub fn span(name: &str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanData {
        name: name.to_string(),
        start: Instant::now(),
        args: Vec::new(),
    }))
}

impl Span {
    /// Attach a key/value argument shown in the trace viewer's detail
    /// pane.  No-op (and no allocation beyond the caller's) when the span
    /// is inert.
    pub fn arg(mut self, key: &'static str, value: impl Into<String>) -> Span {
        if let Some(d) = self.0.as_mut() {
            d.args.push((key, value.into()));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(d) = self.0.take() else {
            return;
        };
        let end = Instant::now();
        let mut guard = lock(&BUF);
        // tracing may have been disabled while the span was open
        let Some(buf) = guard.as_mut() else {
            return;
        };
        // saturates to 0 for spans opened before enable_to
        let ts = d.start.duration_since(buf.start).as_secs_f64() * 1e6;
        let dur = end.duration_since(d.start).as_secs_f64() * 1e6;
        let mut ev = vec![
            ("name", Json::str(d.name)),
            ("cat", Json::str("galen")),
            ("ph", Json::str("X")),
            ("ts", Json::num(ts)),
            ("dur", Json::num(dur)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(thread_id() as f64)),
        ];
        if !d.args.is_empty() {
            ev.push((
                "args",
                Json::Obj(
                    d.args
                        .into_iter()
                        .map(|(k, v)| (k.to_string(), Json::str(v)))
                        .collect(),
                ),
            ));
        }
        buf.events.push(Json::obj(ev));
    }
}
