//! # Galen-RS
//!
//! Production-grade reproduction of *"Towards Hardware-Specific Automatic
//! Compression of Neural Networks"* (Krieger, Klein, Fröning, 2022) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * **L3 (this crate)** — the Galen search framework: DDPG agents for
//!   pruning / quantization / joint compression, the episode loop with
//!   hardware-latency reward, sensitivity analysis, the embedded-CPU latency
//!   simulator substrate, and all experiment harnesses.
//! * **L2/L1 (python/, build-time only)** — the compressible model as a
//!   policy-parameterized JAX graph whose convolutions lower through a fused
//!   Pallas quantize-GEMM kernel; AOT-exported to HLO text under
//!   `artifacts/` and executed here via PJRT (`runtime`).
//!
//! Python never runs on the search path: policies are runtime *inputs* of
//! one compiled artifact (see DESIGN.md "Compression-as-runtime-inputs").

pub mod agent;
pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod eval;
pub mod hw;
pub mod model;
pub mod nn;
pub mod reward;
pub mod runtime;
pub mod search;
pub mod tensor;
pub mod testing;
pub mod util;

/// Repository-root-relative default artifact directory.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("GALEN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Default results directory for experiment harnesses.
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("GALEN_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// Default root of the measured-latency profile caches
/// (`profiles/<target>/<model>.json`, see `hw::MeasuredProfiler`).
pub fn profiles_dir() -> std::path::PathBuf {
    std::env::var("GALEN_PROFILES")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("profiles"))
}
