//! # Galen-RS
//!
//! Production-grade reproduction of *"Towards Hardware-Specific Automatic
//! Compression of Neural Networks"* (Krieger, Klein, Fröning, 2022) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * **L3 (this crate)** — the Galen search framework: DDPG agents for
//!   pruning / quantization / joint compression, the episode loop with
//!   hardware-latency reward, sensitivity analysis, the embedded-CPU latency
//!   simulator substrate, the measured-kernel profiler, the parallel sweep
//!   orchestrator, and all experiment harnesses.
//! * **L2/L1 (python/, build-time only)** — the compressible model as a
//!   policy-parameterized JAX graph whose convolutions lower through a fused
//!   Pallas quantize-GEMM kernel; AOT-exported to HLO text under
//!   `artifacts/` and executed here via PJRT (`runtime`).
//!
//! Python never runs on the search path: policies are runtime *inputs* of
//! one compiled artifact (see DESIGN.md "Compression-as-runtime-inputs").
//!
//! ## Orientation
//!
//! ARCHITECTURE.md at the repository root maps the module graph and the
//! data flow end to end.  The short version, bottom-up:
//!
//! * [`tensor`] — GEMM kernels (f32 blocked/threaded, i8, packed-i8);
//! * [`nn`] / [`agent`] — MLPs, Adam, replay, and the DDPG agents;
//! * [`model`] / [`compress`] — the structural IR and compression policies;
//! * [`hw`] — latency backends behind the pluggable `hw::LatencyProvider`:
//!   analytical simulator, measured-kernel profiler, calibrated hybrid;
//! * [`search`] — the resumable episode-loop state machine
//!   (`search::SearchDriver`: step/episode granularity, `SearchEvent`
//!   observers, bit-identical checkpoint/resume), its one-call wrapper
//!   `search::run_search`, and the parallel Pareto-sweep orchestrator
//!   (`search::run_sweep`);
//! * [`coordinator`] — `coordinator::Session` wires it all together and
//!   persists results; `coordinator::serve` multiplexes concurrent search
//!   jobs over a JSONL protocol (`galen serve`); the `galen` binary is a
//!   thin CLI over both;
//! * [`artifact`] — packages a finished search into a deployable,
//!   checksummed `.galen` file (`galen package` / `galen run-artifact`).
//!
//! ## Quick start (no artifacts required)
//!
//! ```no_run
//! use galen::agent::AgentKind;
//! use galen::coordinator::{Backend, Session, SessionOptions};
//! use galen::search::{SearchConfig, SweepGrid};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut opts = SessionOptions::new("resnet18s");
//! opts.backend = Backend::Synthetic; // no PJRT device needed
//! let session = Session::open(opts)?;
//!
//! // one search ...
//! let outcome = session.search(&SearchConfig::fast(AgentKind::Joint, 0.3))?;
//! println!("relative latency {:.1}%", outcome.relative_latency() * 100.0);
//!
//! // ... or a parallel Pareto sweep across agents x targets
//! let grid = SweepGrid::new(
//!     vec![AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint],
//!     vec![0.2, 0.4, 0.6],
//! );
//! let report = session.sweep_parallel(&grid, &SearchConfig::fast(AgentKind::Joint, 0.3), 0)?;
//! println!("{}", report.front.table());
//! # Ok(())
//! # }
//! ```

#![warn(missing_docs)]

/// The three RL agents (DDPG core, action->policy mappers, replay, state).
pub mod agent;
/// Deployable `.galen` artifacts: signed, checksummed policy + weights.
pub mod artifact;
/// Mini-criterion benchmark harness behind `cargo bench`.
pub mod bench;
/// Policy representations and discretization along the mapping chain.
pub mod compress;
/// Sessions, experiment protocols, and result records.
pub mod coordinator;
/// Accuracy evaluation, retraining, and sensitivity analysis.
pub mod eval;
/// Hardware substrate: latency simulator, measured profiler, providers.
pub mod hw;
/// Structural model IR and the artifact meta manifests.
pub mod model;
/// From-scratch neural-network substrate (MLP + Adam) for the agents.
pub mod nn;
/// Observability: metrics registry, span tracing, snapshots.
pub mod obs;
/// The absolute reward function (paper Eq. 6).
pub mod reward;
/// PJRT runtime: loads and executes the AOT artifacts.
pub mod runtime;
/// The episode loop and the parallel sweep orchestrator.
pub mod search;
/// Matrix types and the f32/i8/packed-i8 GEMM kernels.
pub mod tensor;
/// Property-testing mini-framework (no proptest offline).
pub mod testing;
/// Shared substrates: RNG, JSON, GTEN, stats, CLI, logging, threading.
pub mod util;

/// Repository-root-relative default artifact directory.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("GALEN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Default results directory for experiment harnesses.
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("GALEN_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// Default root of the measured-latency profile caches
/// (`profiles/<target>/<model>.json`, see `hw::MeasuredProfiler`).
pub fn profiles_dir() -> std::path::PathBuf {
    std::env::var("GALEN_PROFILES")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("profiles"))
}

/// Default root of the Pareto-sweep artifacts
/// (`sweeps/<target>/<model>.json`, see `search::ParetoFront`).
pub fn sweeps_dir() -> std::path::PathBuf {
    std::env::var("GALEN_SWEEPS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("sweeps"))
}
