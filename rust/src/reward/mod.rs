//! Reward functions.
//!
//! Primary: the *absolute reward* (Bender et al. 2020) adapted by the paper
//! (Eq. 6): `r(P) = acc + beta * |T_P / (c * T_M) - 1|` with beta < 0.
//! Also provided: the *hard exponential reward* (MnasNet, Tan et al. 2019)
//! the paper tried and rejected — kept for the ablation bench.
//!
//! Both implement the [`RewardModel`] trait so the search driver is
//! reward-agnostic: pick one with [`RewardSpec`] on the
//! `search::SearchBuilder` (or the `reward` key of a JSON config).

use crate::util::json::Json;

/// A scalar reward over one validated policy's (accuracy, latency) pair —
/// the pluggable scoring function of the search driver.
pub trait RewardModel: Send + Sync {
    /// r(P) for a validated policy.
    fn reward(&self, accuracy: f64, latency_s: f64) -> f64;

    /// Which reward family (and shape parameters) this model implements.
    fn spec(&self) -> RewardSpec;
}

/// Declarative choice of reward family, turned into a concrete
/// [`RewardModel`] by [`RewardSpec::build`] once the reference latency is
/// known.  Serializes into configs and driver checkpoints.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum RewardSpec {
    /// The paper's absolute reward (Eq. 6) — the default.
    #[default]
    Absolute,
    /// The hard exponential reward with over-budget exponent `w` (< 0).
    HardExponential {
        /// Over-budget penalty exponent (negative; MnasNet uses -2).
        w: f64,
    },
}

impl RewardSpec {
    /// Instantiate the reward model for a search towards `target` with the
    /// cost exponent `beta` against `base_latency` seconds.  (`beta` only
    /// shapes the absolute reward; the hard exponential uses its own `w`.)
    pub fn build(&self, beta: f64, target: f64, base_latency: f64) -> Box<dyn RewardModel> {
        match *self {
            RewardSpec::Absolute => Box::new(AbsoluteReward::new(beta, target, base_latency)),
            RewardSpec::HardExponential { w } => {
                Box::new(HardExponentialReward::new(w, target, base_latency))
            }
        }
    }

    /// Serialize the spec (config/checkpoint format).
    pub fn to_json(&self) -> Json {
        match *self {
            RewardSpec::Absolute => Json::obj(vec![("kind", Json::str("absolute"))]),
            RewardSpec::HardExponential { w } => Json::obj(vec![
                ("kind", Json::str("hard_exponential")),
                ("w", Json::num(w)),
            ]),
        }
    }

    /// Rebuild a spec serialized by [`RewardSpec::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        match j.req_str("kind")? {
            "absolute" => Ok(RewardSpec::Absolute),
            "hard_exponential" => Ok(RewardSpec::HardExponential { w: j.req_f64("w")? }),
            other => anyhow::bail!("unknown reward kind '{other}' (absolute|hard_exponential)"),
        }
    }
}

/// Parses `absolute` / `hard_exponential` (alias `hardexp`, default w = -2).
impl std::str::FromStr for RewardSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "absolute" => Ok(Self::Absolute),
            "hard_exponential" | "hardexp" => Ok(Self::HardExponential { w: -2.0 }),
            other => anyhow::bail!("unknown reward '{other}' (absolute|hard_exponential)"),
        }
    }
}

/// Stable lowercase family label; honors format padding.
impl std::fmt::Display for RewardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            Self::Absolute => "absolute",
            Self::HardExponential { .. } => "hard_exponential",
        })
    }
}

/// Absolute reward (paper Eq. 6).
#[derive(Clone, Copy, Debug)]
pub struct AbsoluteReward {
    /// Cost exponent beta < 0 (paper experiments: -3.0).
    pub beta: f64,
    /// Target compression rate c (fraction of original latency).
    pub target: f64,
    /// Uncompressed model latency T_M (seconds).
    pub base_latency: f64,
}

impl AbsoluteReward {
    /// A reward for target rate `target` against `base_latency` seconds.
    pub fn new(beta: f64, target: f64, base_latency: f64) -> Self {
        assert!(beta < 0.0, "cost exponent must be negative");
        assert!(target > 0.0 && base_latency > 0.0);
        Self {
            beta,
            target,
            base_latency,
        }
    }

    /// r(P) for a validated policy.
    pub fn reward(&self, accuracy: f64, latency: f64) -> f64 {
        let budget = self.target * self.base_latency;
        accuracy + self.beta.abs() * -((latency / budget - 1.0).abs())
    }
}

impl RewardModel for AbsoluteReward {
    fn reward(&self, accuracy: f64, latency_s: f64) -> f64 {
        AbsoluteReward::reward(self, accuracy, latency_s)
    }

    fn spec(&self) -> RewardSpec {
        RewardSpec::Absolute
    }
}

/// Hard exponential reward (Tan et al. 2019): acc * (T/T0)^w when over
/// budget, acc otherwise.  The paper reports the same instabilities Bender
/// et al. discuss; regenerable via the reward ablation.
#[derive(Clone, Copy, Debug)]
pub struct HardExponentialReward {
    /// Over-budget penalty exponent (negative).
    pub w: f64,
    /// Target compression rate c.
    pub target: f64,
    /// Uncompressed model latency (seconds).
    pub base_latency: f64,
}

impl HardExponentialReward {
    /// A reward with over-budget exponent `w` (< 0) for target rate
    /// `target` against `base_latency` seconds.
    pub fn new(w: f64, target: f64, base_latency: f64) -> Self {
        assert!(w < 0.0, "over-budget exponent must be negative");
        assert!(target > 0.0 && base_latency > 0.0);
        Self {
            w,
            target,
            base_latency,
        }
    }

    /// r(P) for a validated policy.
    pub fn reward(&self, accuracy: f64, latency: f64) -> f64 {
        let budget = self.target * self.base_latency;
        if latency <= budget {
            accuracy
        } else {
            accuracy * (latency / budget).powf(self.w)
        }
    }
}

impl RewardModel for HardExponentialReward {
    fn reward(&self, accuracy: f64, latency_s: f64) -> f64 {
        HardExponentialReward::reward(self, accuracy, latency_s)
    }

    fn spec(&self) -> RewardSpec {
        RewardSpec::HardExponential { w: self.w }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absolute_reward_peaks_on_budget() {
        let r = AbsoluteReward::new(-3.0, 0.3, 0.1);
        let on = r.reward(0.9, 0.03);
        let over = r.reward(0.9, 0.06);
        let under = r.reward(0.9, 0.015);
        assert_eq!(on, 0.9);
        assert!(over < on);
        // Eq. 6 also penalizes under-budget policies (|.|)
        assert!(under < on);
        // 2x over budget with beta=-3: penalty = 3.0
        assert!((over - (0.9 - 3.0)).abs() < 1e-9);
    }

    #[test]
    fn more_accuracy_more_reward() {
        let r = AbsoluteReward::new(-3.0, 0.5, 1.0);
        assert!(r.reward(0.95, 0.5) > r.reward(0.90, 0.5));
    }

    #[test]
    fn beta_scales_penalty() {
        let strict = AbsoluteReward::new(-6.0, 0.3, 1.0);
        let lax = AbsoluteReward::new(-1.0, 0.3, 1.0);
        let (acc, lat) = (0.9, 0.45);
        assert!(strict.reward(acc, lat) < lax.reward(acc, lat));
    }

    #[test]
    #[should_panic]
    fn positive_beta_rejected() {
        AbsoluteReward::new(1.0, 0.3, 1.0);
    }

    #[test]
    fn reward_spec_builds_and_roundtrips() {
        // the builder path produces the same numbers as direct construction
        let m = RewardSpec::Absolute.build(-3.0, 0.3, 0.1);
        assert_eq!(m.reward(0.9, 0.03), AbsoluteReward::new(-3.0, 0.3, 0.1).reward(0.9, 0.03));
        assert_eq!(m.spec(), RewardSpec::Absolute);
        let h = RewardSpec::HardExponential { w: -2.0 }.build(-3.0, 0.3, 1.0);
        assert_eq!(h.reward(0.9, 0.2), 0.9);
        assert!(h.reward(0.9, 0.6) < 0.9);
        assert_eq!(h.spec(), RewardSpec::HardExponential { w: -2.0 });
        // json + FromStr/Display roundtrips
        for spec in [RewardSpec::Absolute, RewardSpec::HardExponential { w: -4.5 }] {
            let back = RewardSpec::from_json(
                &Json::parse(&spec.to_json().dump()).unwrap(),
            )
            .unwrap();
            assert_eq!(back, spec);
        }
        assert_eq!("absolute".parse::<RewardSpec>().unwrap(), RewardSpec::Absolute);
        assert_eq!(
            "hardexp".parse::<RewardSpec>().unwrap(),
            RewardSpec::HardExponential { w: -2.0 }
        );
        assert!("nope".parse::<RewardSpec>().is_err());
        assert_eq!(RewardSpec::Absolute.to_string(), "absolute");
    }

    #[test]
    fn hard_exponential_free_under_budget() {
        let r = HardExponentialReward {
            w: -2.0,
            target: 0.3,
            base_latency: 1.0,
        };
        assert_eq!(r.reward(0.9, 0.2), 0.9);
        assert_eq!(r.reward(0.9, 0.3), 0.9);
        assert!(r.reward(0.9, 0.6) < 0.9);
    }
}
