//! Reward functions.
//!
//! Primary: the *absolute reward* (Bender et al. 2020) adapted by the paper
//! (Eq. 6): `r(P) = acc + beta * |T_P / (c * T_M) - 1|` with beta < 0.
//! Also provided: the *hard exponential reward* (MnasNet, Tan et al. 2019)
//! the paper tried and rejected — kept for the ablation bench.

/// Absolute reward (paper Eq. 6).
#[derive(Clone, Copy, Debug)]
pub struct AbsoluteReward {
    /// Cost exponent beta < 0 (paper experiments: -3.0).
    pub beta: f64,
    /// Target compression rate c (fraction of original latency).
    pub target: f64,
    /// Uncompressed model latency T_M (seconds).
    pub base_latency: f64,
}

impl AbsoluteReward {
    /// A reward for target rate `target` against `base_latency` seconds.
    pub fn new(beta: f64, target: f64, base_latency: f64) -> Self {
        assert!(beta < 0.0, "cost exponent must be negative");
        assert!(target > 0.0 && base_latency > 0.0);
        Self {
            beta,
            target,
            base_latency,
        }
    }

    /// r(P) for a validated policy.
    pub fn reward(&self, accuracy: f64, latency: f64) -> f64 {
        let budget = self.target * self.base_latency;
        accuracy + self.beta.abs() * -((latency / budget - 1.0).abs())
    }
}

/// Hard exponential reward (Tan et al. 2019): acc * (T/T0)^w when over
/// budget, acc otherwise.  The paper reports the same instabilities Bender
/// et al. discuss; regenerable via the reward ablation.
#[derive(Clone, Copy, Debug)]
pub struct HardExponentialReward {
    /// Over-budget penalty exponent (negative).
    pub w: f64,
    /// Target compression rate c.
    pub target: f64,
    /// Uncompressed model latency (seconds).
    pub base_latency: f64,
}

impl HardExponentialReward {
    /// r(P) for a validated policy.
    pub fn reward(&self, accuracy: f64, latency: f64) -> f64 {
        let budget = self.target * self.base_latency;
        if latency <= budget {
            accuracy
        } else {
            accuracy * (latency / budget).powf(self.w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absolute_reward_peaks_on_budget() {
        let r = AbsoluteReward::new(-3.0, 0.3, 0.1);
        let on = r.reward(0.9, 0.03);
        let over = r.reward(0.9, 0.06);
        let under = r.reward(0.9, 0.015);
        assert_eq!(on, 0.9);
        assert!(over < on);
        // Eq. 6 also penalizes under-budget policies (|.|)
        assert!(under < on);
        // 2x over budget with beta=-3: penalty = 3.0
        assert!((over - (0.9 - 3.0)).abs() < 1e-9);
    }

    #[test]
    fn more_accuracy_more_reward() {
        let r = AbsoluteReward::new(-3.0, 0.5, 1.0);
        assert!(r.reward(0.95, 0.5) > r.reward(0.90, 0.5));
    }

    #[test]
    fn beta_scales_penalty() {
        let strict = AbsoluteReward::new(-6.0, 0.3, 1.0);
        let lax = AbsoluteReward::new(-1.0, 0.3, 1.0);
        let (acc, lat) = (0.9, 0.45);
        assert!(strict.reward(acc, lat) < lax.reward(acc, lat));
    }

    #[test]
    #[should_panic]
    fn positive_beta_rejected() {
        AbsoluteReward::new(1.0, 0.3, 1.0);
    }

    #[test]
    fn hard_exponential_free_under_budget() {
        let r = HardExponentialReward {
            w: -2.0,
            target: 0.3,
            base_latency: 1.0,
        };
        assert_eq!(r.reward(0.9, 0.2), 0.9);
        assert_eq!(r.reward(0.9, 0.3), 0.9);
        assert!(r.reward(0.9, 0.6) < 0.9);
    }
}
