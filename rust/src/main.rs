//! `galen` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   search       run a policy search (agent, target, episodes, ...)
//!   sweep        parallel Pareto sweep across agents x targets (--jobs)
//!   serve        long-running JSONL job service (stdin/stdout or --listen)
//!   sequential   prune->quant / quant->prune schemes (Figure 5 protocol)
//!   sensitivity  compute + print the layer sensitivity table (Figure 6)
//!   latency      profile the hardware simulator on a model variant
//!   validate     evaluate a saved policy (accuracy + latency + retrain)
//!   package      freeze a finished search record into a .galen artifact
//!   run-artifact verify a .galen artifact and re-measure its latency claim
//!   report       render saved observability artifacts (--metrics) or an
//!                artifact manifest (--artifact)
//!
//! Every subcommand honors `GALEN_TRACE`: set it to trace the run's spans
//! into `results/trace_<command>.json` (Chrome trace-event format) and
//! write the final metrics snapshot to `results/metrics_<command>.json`.
//!
//! Python never runs here: everything executes against AOT artifacts in
//! `artifacts/` and the analytical hardware substrate.

use anyhow::Result;
use galen::agent::AgentKind;
use galen::compress::DiscretePolicy;
use galen::coordinator::{
    policy_report, serve, serve_listener, Backend, BoundListener, ExperimentRecord, NetOptions,
    ServeOptions, Session, SessionOptions,
};
use galen::eval::{retrain, RetrainCfg, SensitivityConfig, Split};
use galen::hw::LatencyKind;
use galen::search::{SearchConfig, SweepGrid};
use galen::util::cli::Cli;
use galen::util::json::Json;

fn main() {
    galen::util::logging::init(log::LevelFilter::Info);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    if let Some(path) = galen::obs::trace::init_from_env(cmd) {
        log::info!("GALEN_TRACE: tracing spans to {}", path.display());
    }
    let r = match cmd {
        "search" => cmd_search(&rest),
        "sweep" => cmd_sweep(&rest),
        "serve" => cmd_serve(&rest),
        "sequential" => cmd_sequential(&rest),
        "sensitivity" => cmd_sensitivity(&rest),
        "latency" => cmd_latency(&rest),
        "validate" => cmd_validate(&rest),
        "package" => cmd_package(&rest),
        "run-artifact" => cmd_run_artifact(&rest),
        "report" => cmd_report(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    finish_observability(cmd);
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Exit-time observability flush: with `GALEN_TRACE` active, write the
/// final metrics snapshot next to the trace
/// (`results/metrics_<command>.json`) and the Chrome trace itself, then
/// drain buffered stderr.  Best-effort by design — a full disk must not
/// turn a finished search into a failure.
fn finish_observability(cmd: &str) {
    if galen::obs::trace::enabled() {
        let path = galen::results_dir().join(format!("metrics_{cmd}.json"));
        let snap = galen::obs::MetricsSnapshot::capture();
        if let Err(e) = snap.to_json().write_file(&path) {
            log::warn!("metrics snapshot write to {} failed ({e:#})", path.display());
        } else {
            log::info!("metrics snapshot written to {}", path.display());
        }
        match galen::obs::trace::flush() {
            Ok(Some(p)) => log::info!("trace written to {}", p.display()),
            Ok(None) => {}
            Err(e) => log::warn!("trace flush failed ({e:#})"),
        }
    }
    galen::util::logging::flush();
}

fn usage() -> &'static str {
    "galen — hardware-specific automatic compression via reinforcement learning\n\
     \n\
     Usage: galen <command> [options]   (--help per command)\n\
     \n\
     Commands:\n\
       search       run one policy search (pruning|quantization|joint)\n\
       sweep        parallel Pareto sweep across agents x targets (Fig 4)\n\
       serve        JSONL job service over stdin/stdout or --listen sockets\n\
       sequential   two-stage prune/quant schemes (Fig 5)\n\
       sensitivity  layer sensitivity analysis (Fig 6)\n\
       latency      hardware-simulator latency profile\n\
       validate     evaluate a saved policy json (accuracy, latency, retrain)\n\
       package      freeze a search record into a deployable .galen artifact\n\
       run-artifact verify an artifact and re-measure its latency claim\n\
       report       render saved observability artifacts (--metrics --file <snapshot>)\n\
                    or an artifact manifest (--artifact <file.galen>)"
}

/// Session options from the shared base-CLI flags (every subcommand's
/// flags must be wired here exactly once).
fn session_opts(args: &galen::util::cli::Args) -> Result<SessionOptions> {
    let mut opts = SessionOptions::new(args.get("variant"));
    opts.backend = args.get("backend").parse()?;
    if args.has_flag("synthetic") {
        opts.backend = Backend::Synthetic;
    }
    if args.has_flag("paper-sensitivity") {
        opts.sensitivity = SensitivityConfig::paper();
    }
    opts.latency = args.get("latency").parse()?;
    opts.seed = args.get_u64("seed")?;
    Ok(opts)
}

fn common_session(args: &galen::util::cli::Args) -> Result<Session> {
    Session::open(session_opts(args)?)
}

fn base_cli(name: &'static str, about: &'static str) -> Cli {
    Cli::new(name, about)
        .opt("variant", "resnet18s", "model variant (micro|resnet18s|resnet18|mobilenetv2s)")
        .opt("seed", "7", "global seed")
        .opt("episodes", "120", "episodes per search")
        .opt("warmup", "10", "random warm-up episodes")
        .opt("eval-batches", "2", "validation batches per accuracy eval")
        .opt("beta", "-3.0", "reward cost exponent (Eq. 6)")
        .opt("results", "results", "results directory")
        .opt("latency", "sim", "latency backend: sim|measured|hybrid")
        .opt("backend", "pjrt", "accuracy backend: pjrt|synthetic")
        .opt("config", "", "JSON config file with search overrides (configs/*.json)")
        .flag("synthetic", "synthetic accuracy backend (alias for --backend synthetic)")
        .flag("paper-sensitivity", "Fig-6 resolution sensitivity probes")
        .flag("paper-episodes", "use the paper's 310/410 episode counts")
}

fn mk_config(args: &galen::util::cli::Args, agent: AgentKind, target: f64) -> Result<SearchConfig> {
    let mut cfg = if args.has_flag("paper-episodes") {
        SearchConfig::paper(agent, target)
    } else {
        let mut c = SearchConfig::new(agent, target);
        c.episodes = args.get_usize("episodes")?;
        c
    };
    cfg.warmup_episodes = args.get_usize("warmup")?;
    cfg.eval_batches = args.get_usize("eval-batches")?;
    cfg.beta = args.get_f64("beta")?;
    cfg.seed = args.get_u64("seed")?;
    let config_path = args.get("config");
    if !config_path.is_empty() {
        let j = Json::read_file(std::path::Path::new(config_path))?;
        cfg.apply_json(&j)?;
    }
    Ok(cfg)
}

fn cmd_search(argv: &[String]) -> Result<()> {
    let cli = base_cli("galen search", "run one compression policy search")
        .opt("agent", "joint", "pruning|quantization|joint")
        .opt("target", "0.3", "target compression rate c")
        .flag("retrain", "fine-tune the best policy before reporting")
        .flag("no-sensitivity", "ablation: constant sensitivity features");
    let args = cli.parse_from(argv)?;
    let session = common_session(&args)?;
    let agent: AgentKind = args.get("agent").parse()?;
    let target = args.get_f64("target")?;
    let cfg = mk_config(&args, agent, target)?;

    let sens_override = if args.has_flag("no-sensitivity") {
        Some(galen::eval::SensitivityTable::disabled(
            session.ir.layers.len(),
            &session.opts.sensitivity,
            &session.opts.variant,
        ))
    } else {
        None
    };
    let outcome = session.search_from(&cfg, None, sens_override.as_ref())?;

    println!("{}", galen::coordinator::table1_header());
    let rec = ExperimentRecord {
        name: format!(
            "search_{}_{agent}_c{:03}",
            session.opts.variant,
            (target * 100.0) as u32
        ),
        config: cfg,
        outcome,
    };
    println!("{}", rec.table1_row());
    println!(
        "\nBest policy:\n{}",
        policy_report(&session.ir, &rec.outcome.best_policy)
    );

    if args.has_flag("retrain") {
        if let Some(ev) = &session.evaluator {
            let report = retrain(ev, &rec.outcome.best_policy, &RetrainCfg::default())?;
            log::info!(
                "retrain losses: first={:.4} last={:.4}",
                report.losses.first().copied().unwrap_or(0.0),
                report.losses.last().copied().unwrap_or(0.0)
            );
        }
    }
    let path = rec.save(&session.ir, std::path::Path::new(args.get("results")))?;
    log::info!("saved {}", path.display());
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let cli = base_cli(
        "galen sweep",
        "parallel Pareto sweep over agents x targets (Fig 4 protocol)",
    )
    .opt("agents", "pruning,quantization,joint", "agents to sweep")
    .opt("targets", "0.1,0.2,0.3,0.4,0.5,0.6,0.7", "target rates")
    .opt("jobs", "0", "sweep worker threads (0 = all cores)")
    .opt("replicates", "1", "independent seeds per (agent, target) cell")
    .opt("sweeps", "", "Pareto artifact root (default sweeps/, or GALEN_SWEEPS)");
    let args = cli.parse_from(argv)?;
    // Sweep jobs always score accuracy with the deterministic synthetic
    // proxy (the PJRT evaluator is not thread-safe), so never pay PJRT
    // session startup here — validate chosen front points with
    // `galen search` / `galen validate` afterwards.
    if !args.has_flag("synthetic") {
        log::info!(
            "sweep uses the synthetic accuracy proxy; skipping PJRT setup \
             (validate front points with `galen search`/`galen validate`)"
        );
    }
    let mut opts = session_opts(&args)?;
    opts.backend = Backend::Synthetic;
    let session = Session::open(opts)?;
    let targets = args.get_f64_list("targets")?;
    let agents = args
        .get_list("agents")
        .iter()
        .map(|s| s.parse::<AgentKind>())
        .collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(!agents.is_empty() && !targets.is_empty(), "empty sweep grid");
    let proto = mk_config(&args, agents[0], targets[0])?;
    let grid = SweepGrid::new(agents, targets).with_replicates(args.get_usize("replicates")?);

    let report = session.sweep_parallel(&grid, &proto, args.get_usize("jobs")?)?;

    print!("{}", report.job_table());
    for o in &report.outcomes {
        let rec = ExperimentRecord {
            name: format!(
                "sweep_{}_{}_c{:03}_{:08x}",
                session.opts.variant,
                o.job.agent,
                (o.job.target * 100.0) as u32,
                o.job.seed as u32
            ),
            config: {
                let mut cfg = proto.clone();
                cfg.agent = o.job.agent;
                cfg.target = o.job.target;
                cfg.seed = o.job.seed;
                cfg
            },
            outcome: o.outcome.clone(),
        };
        rec.save(&session.ir, std::path::Path::new(args.get("results")))?;
    }

    println!(
        "\nPareto front ({} of {} jobs survive, accuracy proxy vs relative latency):\n{}",
        report.front.points.len(),
        report.outcomes.len(),
        report.front.table()
    );
    let sweeps_root = if args.get("sweeps").is_empty() {
        galen::sweeps_dir()
    } else {
        std::path::PathBuf::from(args.get("sweeps"))
    };
    let path = session.save_sweep(&report, &sweeps_root)?;
    println!("sweep artifact: {}", path.display());
    println!(
        "({} jobs on {} workers in {:.1}s, {} latency backend)",
        report.outcomes.len(),
        report.workers,
        report.wall_s,
        session.opts.latency
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cli = Cli::new(
        "galen serve",
        "long-running search job service: JSONL over stdin/stdout, or TCP/Unix \
         sockets with --listen",
    )
    .opt("variant", "resnet18s", "model variant (micro|resnet18s|resnet18|mobilenetv2s)")
    .opt("seed", "7", "session seed")
    .opt("latency", "sim", "latency backend: sim|measured|hybrid")
    .opt("jobs", "0", "search worker threads (0 = all cores)")
    .opt("results", "results", "record directory for finished jobs ('' disables)")
    .opt(
        "checkpoint-every",
        "1",
        "episodes between driver checkpoints (0 disables; needs --results)",
    )
    .opt(
        "listen",
        "",
        "accept socket clients: host:port (TCP) or unix:<path> ('' = stdio)",
    )
    .opt("max-connections", "64", "concurrent socket clients (0 = unlimited; needs --listen)")
    .opt("max-queued", "0", "reject submits past this queue depth (0 = unbounded)")
    .opt("retry-after-ms", "500", "backoff hint attached to admission rejections")
    .opt(
        "package-dir",
        "",
        "package each finished job into this artifact root ('' disables)",
    )
    .opt("sign-key", "", "HMAC key for signing packaged artifacts (or GALEN_SIGN_KEY)")
    .flag("resume-jobs", "replay the serve journal and resume interrupted jobs")
    .flag("fixture", "use the in-code tiny fixture IR (no artifacts needed)");
    let args = cli.parse_from(argv)?;
    // Accuracy is always the synthetic proxy here: stdout is the protocol
    // channel and the PJRT evaluator is not thread-safe — validate chosen
    // policies afterwards with `galen validate`.
    let session = if args.has_flag("fixture") {
        Session::fixture(args.get("latency").parse()?, args.get_u64("seed")?)?
    } else {
        let mut opts = SessionOptions::new(args.get("variant"));
        opts.backend = Backend::Synthetic;
        opts.latency = args.get("latency").parse()?;
        opts.seed = args.get_u64("seed")?;
        Session::open(opts)?
    };
    // fault injection (GALEN_FAULTS) reaches both the job loop and the
    // measured-latency providers; the plan is empty unless the env var is set
    let faults = galen::testing::FaultPlan::from_env()?;
    let factory = session.latency_factory().with_faults(faults.clone());
    let results = args.get("results");
    let results_dir = if results.is_empty() {
        None
    } else {
        Some(std::path::PathBuf::from(results))
    };
    anyhow::ensure!(
        !(args.has_flag("resume-jobs") && results_dir.is_none()),
        "--resume-jobs needs a results directory (the journal lives there)"
    );
    let package_dir = args.get("package-dir");
    let packager = if package_dir.is_empty() {
        None
    } else {
        Some(session.packager(std::path::PathBuf::from(package_dir), sign_key(&args))?)
    };
    let opts = ServeOptions {
        workers: args.get_usize("jobs")?,
        results_dir: results_dir.clone(),
        base_seed: Some(args.get_u64("seed")?),
        journal_dir: results_dir,
        resume_jobs: args.has_flag("resume-jobs"),
        checkpoint_every: args.get_usize("checkpoint-every")?,
        max_queued_jobs: args.get_usize("max-queued")?,
        retry_after_ms: args.get_u64("retry-after-ms")?,
        faults,
        packager,
    };
    let listen = args.get("listen");
    let stats = if listen.is_empty() {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        serve(
            &session.ir,
            &session.sens,
            &factory,
            &session.opts.variant,
            &opts,
            stdin.lock(),
            &mut stdout.lock(),
        )?
    } else {
        let net = NetOptions { max_connections: args.get_usize("max-connections")? };
        let listener = BoundListener::bind(listen)?;
        // the protocol moved to the socket, so stdout is free: announce
        // the resolved address (port 0 binds an ephemeral port — scripts
        // parse this line to find it)
        println!("listening on {}", listener.local_addr());
        use std::io::Write as _;
        std::io::stdout().flush()?;
        serve_listener(
            &session.ir,
            &session.sens,
            &factory,
            &session.opts.variant,
            &opts,
            &net,
            listener,
        )?
    };
    anyhow::ensure!(
        stats.failed == 0,
        "{} of {} jobs failed (see the per-job error responses)",
        stats.failed,
        stats.submitted + stats.resumed
    );
    Ok(())
}

fn cmd_sequential(argv: &[String]) -> Result<()> {
    let cli = base_cli("galen sequential", "two-stage schemes vs joint (Fig 5)")
        .opt("target", "0.2", "effective target compression rate")
        .opt("first", "pruning", "first stage: pruning|quantization");
    let args = cli.parse_from(argv)?;
    let session = common_session(&args)?;
    let target = args.get_f64("target")?;
    let first: AgentKind = args.get("first").parse()?;
    let proto = mk_config(&args, first, target)?;
    let (s1, s2) = session.sequential(first, target, &proto)?;
    println!(
        "stage 1 ({first}): rel.lat {:.1}%  acc {:.2}%",
        s1.relative_latency() * 100.0,
        s1.best.accuracy * 100.0
    );
    println!(
        "stage 2: rel.lat {:.1}%  acc {:.2}%\n\nFinal policy:\n{}",
        s2.relative_latency() * 100.0,
        s2.best.accuracy * 100.0,
        policy_report(&session.ir, &s2.best_policy)
    );
    Ok(())
}

fn cmd_sensitivity(argv: &[String]) -> Result<()> {
    let cli = base_cli("galen sensitivity", "layer sensitivity table (Fig 6)");
    let args = cli.parse_from(argv)?;
    let session = common_session(&args)?;
    let sens = &session.sens;
    println!(
        "{:14} {:>34} {:>34}",
        "layer", "w-quant Ω (bits asc)", "prune Ω (ratio asc)"
    );
    for l in &session.ir.layers {
        let w: Vec<String> = sens.quant_w[l.index]
            .iter()
            .map(|p| format!("{:.3}", p.omega))
            .collect();
        let pr: Vec<String> = sens.prune[l.index]
            .iter()
            .map(|p| format!("{:.3}", p.omega))
            .collect();
        println!("{:14} {:>34} {:>34}", l.name, w.join(" "), pr.join(" "));
    }
    Ok(())
}

fn cmd_latency(argv: &[String]) -> Result<()> {
    let cli = base_cli("galen latency", "hardware latency profile (sim or measured)");
    let args = cli.parse_from(argv)?;
    let mut opts = SessionOptions::new(args.get("variant"));
    opts.backend = Backend::Synthetic; // structure only
    opts.latency = args.get("latency").parse()?;
    opts.seed = args.get_u64("seed")?;
    let session = Session::open(opts)?;
    let p = DiscretePolicy::reference(&session.ir);
    // A per-layer profile is either simulated or measured; a hybrid request
    // degrades to the full measured profile (and says so) rather than
    // mislabeling measured numbers as calibrated-hybrid output.
    let (per_layer, backend) = match session.opts.latency {
        LatencyKind::Sim => (session.simulator(1).latency_per_layer(&session.ir, &p), "sim"),
        LatencyKind::Measured | LatencyKind::Hybrid => {
            if session.opts.latency == LatencyKind::Hybrid {
                log::info!(
                    "latency profile has no calibrated-fallback path; measuring every layer"
                );
            }
            let mut prof = session.profiler()?;
            let t = prof.model_latency_per_layer(&session.ir, &p);
            if let Some(path) = prof.save()? {
                log::info!("profile cache written to {}", path.display());
            }
            (t, "measured")
        }
    };
    println!("{:14} {:>12} {:>10}", "layer", "latency", "share");
    let total: f64 = per_layer.iter().sum();
    for (l, t) in session.ir.layers.iter().zip(&per_layer) {
        println!("{:14} {:>9.3} ms {:>9.1}%", l.name, t * 1e3, 100.0 * t / total);
    }
    println!("total {:.3} ms (fp32 reference, {backend} backend)", total * 1e3);
    Ok(())
}

fn cmd_validate(argv: &[String]) -> Result<()> {
    let cli = base_cli("galen validate", "evaluate a saved policy record")
        .req("policy", "path to a results/*.json record")
        .flag("retrain", "fine-tune before the test-split evaluation")
        .flag("test-split", "report test accuracy instead of validation");
    let args = cli.parse_from(argv)?;
    let session = common_session(&args)?;
    let j = Json::read_file(std::path::Path::new(args.get("policy")))?;
    let policy = parse_policy(&session, &j)?;

    let sim = session.simulator(args.get_u64("seed")?);
    let lat = sim.latency(&session.ir, &policy);
    println!("latency: {:.3} ms", lat * 1e3);
    if let Some(ev) = &session.evaluator {
        let split = if args.has_flag("test-split") {
            Split::Test
        } else {
            Split::Val
        };
        let acc = ev.accuracy(&policy, split, usize::MAX)?;
        println!("accuracy ({split:?}): {:.2}%", acc * 100.0);
        if args.has_flag("retrain") {
            let rep = retrain(ev, &policy, &RetrainCfg::default())?;
            log::info!("retrained {} steps", rep.losses.len());
        }
    }
    println!("{}", policy_report(&session.ir, &policy));
    Ok(())
}

/// Synthetic-backend session for artifact packaging and verification: the
/// `tiny` variant maps to the in-code fixture IR, everything else resolves
/// through the artifact meta manifests / model zoo.
fn artifact_session(variant: &str, latency: &str, seed: u64) -> Result<Session> {
    if variant == "tiny" {
        Session::fixture(latency.parse()?, seed)
    } else {
        let mut opts = SessionOptions::new(variant);
        opts.backend = Backend::Synthetic;
        opts.latency = latency.parse()?;
        opts.seed = seed;
        Session::open(opts)
    }
}

/// Resolve the artifact HMAC signing key: `--sign-key` wins, else the
/// `GALEN_SIGN_KEY` environment variable; empty means unsigned.
fn sign_key(args: &galen::util::cli::Args) -> Option<Vec<u8>> {
    let k = args.get("sign-key");
    if !k.is_empty() {
        return Some(k.as_bytes().to_vec());
    }
    std::env::var("GALEN_SIGN_KEY")
        .ok()
        .filter(|s| !s.is_empty())
        .map(String::into_bytes)
}

fn cmd_package(argv: &[String]) -> Result<()> {
    let cli = Cli::new(
        "galen package",
        "freeze a finished search record into a deployable .galen artifact",
    )
    .req("record", "path to a results/*.json search record")
    .opt("variant", "resnet18s", "model variant the record was searched on (tiny = fixture)")
    .opt("seed", "7", "session seed")
    .opt("latency", "sim", "session latency backend: sim|measured|hybrid")
    .opt("out", "", "artifact root (default artifacts/, or GALEN_ARTIFACTS)")
    .opt("sign-key", "", "HMAC-SHA256 manifest signing key (or GALEN_SIGN_KEY)");
    let args = cli.parse_from(argv)?;
    let session =
        artifact_session(args.get("variant"), args.get("latency"), args.get_u64("seed")?)?;
    let j = Json::read_file(std::path::Path::new(args.get("record")))?;
    let policy = parse_policy(&session, &j)?;
    // rebuild the latency claim from the record's persisted outcome so the
    // artifact carries exactly what the search reported, not a re-measurement
    let outcome = j.req("outcome")?;
    let claim = galen::artifact::LatencyClaim {
        latency_s: outcome.req("best")?.req_f64("latency_s")?,
        base_latency_s: outcome.req_f64("base_latency_s")?,
        backend: outcome.req_str("latency_backend")?.to_string(),
    };
    let (weights, weights_source) = session.packaging_weights()?;
    let root = if args.get("out").is_empty() {
        galen::artifacts_dir()
    } else {
        std::path::PathBuf::from(args.get("out"))
    };
    let key = sign_key(&args);
    let path = session.package(&policy, claim, &weights, weights_source, &root, key.as_deref())?;
    println!("artifact: {}", path.display());
    Ok(())
}

fn cmd_run_artifact(argv: &[String]) -> Result<()> {
    use galen::artifact::{self, DriftReport, VerifyOptions};
    let cli = Cli::new(
        "galen run-artifact",
        "verify a .galen artifact end to end and re-measure its latency claim",
    )
    .req("artifact", "path to a .galen artifact")
    .opt("seed", "7", "session seed for the re-measurement")
    .opt("latency", "sim", "re-measurement backend: sim|measured|hybrid")
    .opt("drift-tolerance", "0.25", "max |measured-claimed|/claimed before failing")
    .opt("sign-key", "", "HMAC key the manifest signature must verify against (or GALEN_SIGN_KEY)")
    .flag("require-signature", "reject unsigned artifacts")
    .flag("allow-foreign-target", "only warn when the target fingerprint differs");
    let args = cli.parse_from(argv)?;
    let vopts = VerifyOptions {
        hmac_key: sign_key(&args),
        require_signature: args.has_flag("require-signature"),
    };
    // every checksum, the schema version, and (when keyed) the signature are
    // checked before any weight bytes are interpreted
    let loaded = artifact::load_with(std::path::Path::new(args.get("artifact")), &vopts)?;
    let m = &loaded.manifest;
    print!("{}", m.table());
    let session = artifact_session(&m.variant, args.get("latency"), args.get_u64("seed")?)?;
    artifact::check_against_ir(&loaded, &session.ir)?;
    let fp = session.opts.target_hw.fingerprint_hex();
    if m.target_fingerprint != fp {
        let msg = format!(
            "target fingerprint mismatch: artifact {} vs session {fp} ({})",
            m.target_fingerprint, session.opts.target_hw.name
        );
        anyhow::ensure!(
            args.has_flag("allow-foreign-target"),
            "{msg} (pass --allow-foreign-target to override)"
        );
        log::warn!("{msg}");
    }
    println!(
        "verified: {} payload sections, signature {}",
        loaded.payload.sections.len(),
        if loaded.signature_verified { "verified" } else { "absent" }
    );
    let mut provider = session.latency_provider(args.get_u64("seed")?)?;
    let measured = provider.latency(&session.ir, &m.policy);
    provider.persist()?;
    let report =
        DriftReport::new(m.claim.latency_s, measured, args.get_f64("drift-tolerance")?);
    println!(
        "latency [{} backend vs claimed {}]: {report}",
        provider.backend(),
        m.claim.backend
    );
    anyhow::ensure!(report.within_tolerance(), "latency drift gate failed: {report}");
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new(
        "galen report",
        "render saved observability artifacts as human-readable tables",
    )
    .opt("file", "", "metrics snapshot json (results/metrics_<command>.json)")
    .opt("artifact", "", "render the verified manifest of a .galen artifact")
    .opt("sign-key", "", "HMAC key for --artifact signature checking (or GALEN_SIGN_KEY)")
    .flag("metrics", "render a metrics snapshot (schema-checked) as a table");
    let args = cli.parse_from(argv)?;
    let artifact_path = args.get("artifact");
    anyhow::ensure!(
        args.has_flag("metrics") || !artifact_path.is_empty(),
        "nothing to report: pass --metrics --file <snapshot> and/or --artifact <file.galen>"
    );
    if args.has_flag("metrics") {
        let file = args.get("file");
        anyhow::ensure!(!file.is_empty(), "--metrics needs --file <path>");
        let doc = Json::read_file(std::path::Path::new(file))?;
        let snap = galen::obs::MetricsSnapshot::from_json(&doc)?;
        print!("{}", snap.table());
    }
    if !artifact_path.is_empty() {
        let vopts = galen::artifact::VerifyOptions {
            hmac_key: sign_key(&args),
            require_signature: false,
        };
        let loaded =
            galen::artifact::load_with(std::path::Path::new(artifact_path), &vopts)?;
        print!("{}", loaded.manifest.table());
    }
    Ok(())
}

/// Parse the `policy` array of a saved record back into a DiscretePolicy.
fn parse_policy(session: &Session, j: &Json) -> Result<DiscretePolicy> {
    use galen::compress::{LayerCmp, QuantMode};
    let arr = j.req_arr("policy")?;
    anyhow::ensure!(arr.len() == session.ir.layers.len(), "layer count mismatch");
    let mut layers = Vec::with_capacity(arr.len());
    for (l, e) in session.ir.layers.iter().zip(arr) {
        anyhow::ensure!(e.req_str("layer")? == l.name, "layer order mismatch");
        let channels = e.req_usize("channels")?;
        let wb = e.req_f64("w_bits")? as u32;
        let ab = e.req_f64("a_bits")? as u32;
        let quant = match (wb, ab) {
            (32, 32) => QuantMode::Fp32,
            (8, 8) => QuantMode::Int8,
            (w, a) => QuantMode::Mix {
                w_bits: w as u8,
                a_bits: a as u8,
            },
        };
        layers.push(LayerCmp {
            kept_channels: channels,
            quant,
        });
    }
    Ok(DiscretePolicy { layers })
}
