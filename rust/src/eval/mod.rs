//! Policy evaluation: accuracy via the PJRT forward artifact, KL-divergence
//! sensitivity analysis (paper Eq. 5), and post-search fine-tuning through
//! the AOT train-step graph.

mod evaluator;
mod retrain;
mod sensitivity;

pub use evaluator::{Evaluator, Split};
pub use retrain::{retrain, RetrainCfg, RetrainReport};
pub use sensitivity::{SensitivityConfig, SensitivityProbe, SensitivityTable};
