//! Post-search fine-tuning through the AOT train-step artifact (frozen-BN
//! SGD-momentum with STE quantizers — paper: "reported accuracies are test
//! accuracies of the compressed and for 30 epochs retrained models").
//!
//! Input contract of `train_step_<variant>.hlo.txt` (aot.py):
//!   [x, y(i32), lr, *params, *moms (trainable order), *policy]
//! Outputs: [loss, *new_trainable_params, *new_moms].

use anyhow::{ensure, Result};

use super::evaluator::Evaluator;
use crate::compress::{DiscretePolicy, PolicyInputs};
use crate::runtime::HostTensor;
use crate::util::rng::Pcg64;

/// Fine-tuning schedule.
#[derive(Clone, Copy, Debug)]
pub struct RetrainCfg {
    /// SGD-momentum steps.
    pub steps: usize,
    /// Learning rate.
    pub lr: f32,
    /// Batch-order shuffle seed.
    pub seed: u64,
}

impl Default for RetrainCfg {
    fn default() -> Self {
        Self {
            steps: 60,
            lr: 5e-3,
            seed: 99,
        }
    }
}

/// What `retrain` produced.
#[derive(Clone, Debug)]
pub struct RetrainReport {
    /// Per-step training losses.
    pub losses: Vec<f32>,
    /// Parameters after fine-tuning, full manifest order.
    pub params: Vec<HostTensor>,
}

/// Fine-tune the compressed model; returns the tuned parameters without
/// mutating the evaluator (callers decide whether to `set_params`).
pub fn retrain(ev: &Evaluator, policy: &DiscretePolicy, cfg: &RetrainCfg) -> Result<RetrainReport> {
    let reg = &ev.reg;
    let ts = reg
        .train_step
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("no train_step artifact for {}", reg.variant))?;
    let batch = reg.meta.train_batch;
    let trainable = &reg.meta.trainable;
    let mut params: Vec<HostTensor> = reg.params.clone();
    let mut moms: Vec<HostTensor> = trainable
        .iter()
        .map(|&i| HostTensor::new(params[i].shape.clone(), vec![0.0; params[i].numel()]))
        .collect();

    // policy inputs are constant across steps
    let pol = PolicyInputs::build(&reg.ir, policy, &reg.params_by_name)?;
    let pol_tensors: Vec<HostTensor> = pol
        .buffers
        .into_iter()
        .zip(&reg.meta.policy)
        .map(|(buf, e)| HostTensor::new(e.shape.clone(), buf))
        .collect();
    let pol_dev = ev.runtime.upload(&pol_tensors)?;

    let img_elems: usize = reg.dataset.retrain_x.shape[1..].iter().product();
    let n = reg.dataset.retrain_x.shape[0];
    ensure!(n >= batch, "retrain pool smaller than a batch");

    let mut rng = Pcg64::with_stream(cfg.seed, 0x7e7a);
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut shape = reg.dataset.retrain_x.shape.clone();
    shape[0] = batch;

    for _step in 0..cfg.steps {
        // sample a batch
        let idx = rng.sample_indices(n, batch);
        let mut x = Vec::with_capacity(batch * img_elems);
        let mut y = Vec::with_capacity(batch);
        for &i in &idx {
            x.extend_from_slice(&reg.dataset.retrain_x.data[i * img_elems..(i + 1) * img_elems]);
            y.push(reg.dataset.retrain_y[i]);
        }
        let xbuf = ev.runtime.upload_one(&HostTensor::new(shape.clone(), x))?;
        let ybuf = ev.runtime.upload_i32(&y, &[batch])?;
        let lrbuf = ev.runtime.upload_one(&HostTensor::scalar(cfg.lr))?;

        let params_dev = ev.runtime.upload(&params)?;
        let moms_dev = ev.runtime.upload(&moms)?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
        args.push(&xbuf);
        args.push(&ybuf);
        args.push(&lrbuf);
        args.extend(params_dev.bufs.iter());
        args.extend(moms_dev.bufs.iter());
        args.extend(pol_dev.bufs.iter());

        let out = ts.run_b(&args)?;
        ensure!(
            out.len() == 1 + 2 * trainable.len(),
            "train_step returned {} outputs, expected {}",
            out.len(),
            1 + 2 * trainable.len()
        );
        losses.push(out[0].data[0]);
        for (j, &pi) in trainable.iter().enumerate() {
            params[pi] = out[1 + j].clone();
        }
        for (j, m) in moms.iter_mut().enumerate() {
            *m = out[1 + trainable.len() + j].clone();
        }
    }

    Ok(RetrainReport { losses, params })
}
