//! Accuracy/probability evaluation of compressed models on the PJRT
//! runtime.
//!
//! The forward artifact's input contract (aot.py) is
//! `[x, *params (manifest order), *policy (manifest order)]`.
//! Parameters and evaluation batches are uploaded to the device once at
//! construction; per-policy calls upload only the small mask/bit tensors.

use anyhow::{ensure, Result};

use std::collections::BTreeMap;

use crate::compress::{precompute_rankings, DiscretePolicy, PolicyInputs};
use crate::runtime::{ArtifactRegistry, DeviceTensors, HostTensor, PjrtRuntime};

/// Which dataset split an evaluation runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// Validation split: drives the search reward + sensitivity analysis.
    Val,
    /// Test split: only for final reported accuracies.
    Test,
}

/// Device-cached batches of one split.
struct DeviceSplit {
    x: Vec<xla::PjRtBuffer>,
    y: Vec<Vec<i32>>,
}

/// Accuracy evaluation through the PJRT forward artifact, with model
/// parameters and dataset batches cached on device.
pub struct Evaluator {
    /// The PJRT client everything executes on.
    pub runtime: PjrtRuntime,
    /// Compiled artifacts + dataset + IR of the variant.
    pub reg: ArtifactRegistry,
    dev_params: DeviceTensors,
    val: DeviceSplit,
    test: DeviceSplit,
    batch: usize,
    /// Reference (uncompressed) softmax probabilities per val batch, lazily
    /// computed: the sensitivity analysis' KL baseline.
    ref_probs: std::cell::RefCell<Option<Vec<Vec<f32>>>>,
    /// Forward executions performed (profiling counter).
    pub fwd_calls: std::cell::Cell<u64>,
    /// ℓ1 channel rankings, precomputed once (§Perf: weights are fixed).
    rankings: BTreeMap<String, Vec<usize>>,
}

fn batches(
    runtime: &PjrtRuntime,
    x: &HostTensor,
    y: &[i32],
    batch: usize,
) -> Result<DeviceSplit> {
    let img_elems: usize = x.shape[1..].iter().product();
    let n = x.shape[0];
    let mut bx = Vec::new();
    let mut by = Vec::new();
    let full = n / batch;
    for b in 0..full {
        let lo = b * batch;
        let data = &x.data[lo * img_elems..(lo + batch) * img_elems];
        let mut shape = x.shape.clone();
        shape[0] = batch;
        bx.push(runtime.upload_one(&HostTensor::new(shape, data.to_vec()))?);
        by.push(y[lo..lo + batch].to_vec());
    }
    Ok(DeviceSplit { x: bx, y: by })
}

impl Evaluator {
    /// Upload parameters and dataset batches; precompute channel rankings.
    pub fn new(runtime: PjrtRuntime, reg: ArtifactRegistry) -> Result<Self> {
        let batch = reg.meta.eval_batch;
        ensure!(batch > 0, "eval batch must be positive");
        let dev_params = runtime.upload(&reg.params)?;
        let val = batches(&runtime, &reg.dataset.val_x, &reg.dataset.val_y, batch)?;
        let test = batches(&runtime, &reg.dataset.test_x, &reg.dataset.test_y, batch)?;
        let rankings = precompute_rankings(&reg.ir, &reg.params_by_name);
        Ok(Self {
            runtime,
            reg,
            dev_params,
            val,
            test,
            batch,
            ref_probs: std::cell::RefCell::new(None),
            fwd_calls: std::cell::Cell::new(0),
            rankings,
        })
    }

    /// The artifact's evaluation batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Number of device-cached batches of `split`.
    pub fn num_batches(&self, split: Split) -> usize {
        match split {
            Split::Val => self.val.x.len(),
            Split::Test => self.test.x.len(),
        }
    }

    fn split(&self, split: Split) -> &DeviceSplit {
        match split {
            Split::Val => &self.val,
            Split::Test => &self.test,
        }
    }

    fn upload_policy(&self, policy: &DiscretePolicy) -> Result<DeviceTensors> {
        let inputs = PolicyInputs::build_with_rankings(&self.reg.ir, policy, &self.rankings)?;
        let tensors: Vec<HostTensor> = inputs
            .buffers
            .into_iter()
            .zip(&self.reg.meta.policy)
            .map(|(buf, entry)| HostTensor::new(entry.shape.clone(), buf))
            .collect();
        self.runtime.upload(&tensors)
    }

    /// Logits of one batch under `policy_bufs`.
    fn logits(
        &self,
        xbuf: &xla::PjRtBuffer,
        policy_bufs: &DeviceTensors,
    ) -> Result<HostTensor> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.dev_params.len() + policy_bufs.len());
        args.push(xbuf);
        args.extend(self.dev_params.bufs.iter());
        args.extend(policy_bufs.bufs.iter());
        let mut out = self.reg.fwd.run_b(&args)?;
        self.fwd_calls.set(self.fwd_calls.get() + 1);
        ensure!(out.len() == 1, "fwd artifact returned {} outputs", out.len());
        Ok(out.remove(0))
    }

    /// Top-1 accuracy of `policy` over the first `max_batches` batches.
    pub fn accuracy(
        &self,
        policy: &DiscretePolicy,
        split: Split,
        max_batches: usize,
    ) -> Result<f64> {
        let policy_bufs = self.upload_policy(policy)?;
        let s = self.split(split);
        let nb = s.x.len().min(max_batches.max(1));
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..nb {
            let logits = self.logits(&s.x[b], &policy_bufs)?;
            let classes = logits.shape[1];
            for (i, &label) in s.y[b].iter().enumerate() {
                let row = &logits.data[i * classes..(i + 1) * classes];
                let pred = argmax(row);
                correct += (pred == label as usize) as usize;
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Softmax probabilities of `policy` on val batch `b` (row-major [B,C]).
    pub fn probs(&self, policy: &DiscretePolicy, b: usize) -> Result<Vec<f32>> {
        let policy_bufs = self.upload_policy(policy)?;
        let logits = self.logits(&self.val.x[b], &policy_bufs)?;
        Ok(softmax_rows(&logits.data, logits.shape[1]))
    }

    /// Reference (uncompressed) probabilities on val batch `b` (cached).
    pub fn ref_probs(&self, b: usize) -> Result<Vec<f32>> {
        {
            let cache = self.ref_probs.borrow();
            if let Some(all) = cache.as_ref() {
                return Ok(all[b].clone());
            }
        }
        let reference = DiscretePolicy::reference(&self.reg.ir);
        let mut all = Vec::with_capacity(self.val.x.len());
        for i in 0..self.val.x.len() {
            all.push(self.probs(&reference, i)?);
        }
        let out = all[b].clone();
        *self.ref_probs.borrow_mut() = Some(all);
        Ok(out)
    }

    /// Replace the device-resident parameters (after fine-tuning).
    pub fn set_params(&mut self, params: &[HostTensor]) -> Result<()> {
        ensure!(params.len() == self.reg.params.len());
        self.dev_params = self.runtime.upload(params)?;
        *self.ref_probs.borrow_mut() = None;
        Ok(())
    }

    /// Restore the original trained parameters.
    pub fn reset_params(&mut self) -> Result<()> {
        let params = self.reg.params.clone();
        self.set_params(&params)
    }
}

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    let _ = best;
    // manual loop above avoids NaN-poisoned partial_cmp sorts
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

pub(crate) fn softmax_rows(logits: &[f32], classes: usize) -> Vec<f32> {
    let rows = logits.len() / classes;
    let mut out = vec![0.0f32; logits.len()];
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &x) in out[r * classes..(r + 1) * classes].iter_mut().zip(row) {
            *o = (x - m).exp();
            sum += *o;
        }
        for o in &mut out[r * classes..(r + 1) * classes] {
            *o /= sum;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_normalized() {
        let p = softmax_rows(&[1.0, 2.0, 3.0, -1.0, 0.0, 1.0], 3);
        for r in 0..2 {
            let s: f32 = p[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }
}
