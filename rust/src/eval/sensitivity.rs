//! Sensitivity analysis (paper Eq. 5, generalizing ZeroQ): the distortion
//! of compressing a *single layer* with a specific CMP, measured as the
//! KL divergence between the compressed and the reference model's output
//! distributions over N validation samples.
//!
//! The full table is computed once up front per search (paper: "the
//! complete sensitivity analysis is done upfront the search for all
//! layers") and cached to `results/sensitivity_<variant>.json`.

use std::path::Path;

use anyhow::Result;

use super::evaluator::Evaluator;
use crate::compress::{DiscretePolicy, QuantMode};
use crate::util::json::Json;

/// Probe grid configuration.
#[derive(Clone, Debug)]
pub struct SensitivityConfig {
    /// Pruning ratios probed per layer (fraction of channels removed).
    pub prune_ratios: Vec<f64>,
    /// Bit widths probed for weight quantization (activation at max).
    pub w_bits: Vec<u8>,
    /// Bit widths probed for activation quantization (weights at max).
    pub a_bits: Vec<u8>,
    /// Validation batches averaged per probe (N = batches * batch_size).
    pub batches: usize,
}

impl Default for SensitivityConfig {
    fn default() -> Self {
        Self {
            prune_ratios: vec![0.25, 0.5, 0.75, 0.9375],
            w_bits: vec![1, 2, 4, 6, 8],
            a_bits: vec![1, 2, 4, 6, 8],
            batches: 1,
        }
    }
}

impl SensitivityConfig {
    /// The paper's Fig-6 resolution: 10 uniform sparsity points, all bit widths.
    pub fn paper() -> Self {
        Self {
            prune_ratios: (1..=10).map(|i| i as f64 / 10.0).collect(),
            w_bits: (1..=8).collect(),
            a_bits: (1..=8).collect(),
            batches: 1,
        }
    }
}

/// One probed point: the CMP value and its measured distortion Ω.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SensitivityProbe {
    /// The probed CMP value (ratio removed, or bits).
    pub value: f64,
    /// Measured KL distortion Ω at that value.
    pub omega: f64,
}

/// Per-layer probe series for each compression method.
#[derive(Clone, Debug, Default)]
pub struct SensitivityTable {
    /// Model variant the table was computed for.
    pub variant: String,
    /// `[layer][probe]` — pruning (value = ratio removed).
    pub prune: Vec<Vec<SensitivityProbe>>,
    /// `[layer][probe]` — weight quantization (value = bits).
    pub quant_w: Vec<Vec<SensitivityProbe>>,
    /// `[layer][probe]` — activation quantization (value = bits).
    pub quant_a: Vec<Vec<SensitivityProbe>>,
}

/// KL(p || q) averaged over rows, with flooring for numerical safety.
pub fn kl_divergence(p: &[f32], q: &[f32], classes: usize) -> f64 {
    assert_eq!(p.len(), q.len());
    let rows = p.len() / classes;
    let mut total = 0.0f64;
    for r in 0..rows {
        let mut kl = 0.0f64;
        for c in 0..classes {
            let pi = (p[r * classes + c] as f64).max(1e-10);
            let qi = (q[r * classes + c] as f64).max(1e-10);
            kl += pi * (pi / qi).ln();
        }
        total += kl;
    }
    total / rows as f64
}

impl SensitivityTable {
    /// Measure Ω for a single-layer policy deviation over `cfg.batches`.
    fn omega(ev: &Evaluator, policy: &DiscretePolicy, batches: usize) -> Result<f64> {
        let classes = ev.reg.ir.classes;
        let nb = batches.clamp(1, ev.num_batches(super::Split::Val));
        let mut acc = 0.0;
        for b in 0..nb {
            let p = ev.probs(policy, b)?;
            let q = ev.ref_probs(b)?;
            acc += kl_divergence(&p, &q, classes);
        }
        Ok(acc / nb as f64)
    }

    /// Run the full upfront analysis.
    pub fn compute(ev: &Evaluator, cfg: &SensitivityConfig) -> Result<Self> {
        let ir = &ev.reg.ir;
        let reference = DiscretePolicy::reference(ir);
        let mut table = Self {
            variant: ir.variant.clone(),
            ..Default::default()
        };
        let max_bits = 8u8;
        for l in &ir.layers {
            let mut prune = Vec::new();
            // pruning probes: every layer gets probed (even group members —
            // their *measured* sensitivity is what tells the agent they are
            // load-bearing), but ratios are discretized to channel counts.
            for &ratio in &cfg.prune_ratios {
                let kept = (((1.0 - ratio) * l.cout as f64).floor() as usize).max(1);
                let mut p = reference.clone();
                p.layers[l.index].kept_channels = kept;
                prune.push(SensitivityProbe {
                    value: ratio,
                    omega: Self::omega(ev, &p, cfg.batches)?,
                });
            }
            let mut qw = Vec::new();
            for &bits in &cfg.w_bits {
                let mut p = reference.clone();
                p.layers[l.index].quant = QuantMode::Mix {
                    w_bits: bits,
                    a_bits: max_bits,
                };
                qw.push(SensitivityProbe {
                    value: bits as f64,
                    omega: Self::omega(ev, &p, cfg.batches)?,
                });
            }
            let mut qa = Vec::new();
            for &bits in &cfg.a_bits {
                let mut p = reference.clone();
                p.layers[l.index].quant = QuantMode::Mix {
                    w_bits: max_bits,
                    a_bits: bits,
                };
                qa.push(SensitivityProbe {
                    value: bits as f64,
                    omega: Self::omega(ev, &p, cfg.batches)?,
                });
            }
            log::debug!(
                "sensitivity[{}]: prune {:?} qw {:?}",
                l.name,
                prune.iter().map(|p| p.omega).collect::<Vec<_>>(),
                qw.iter().map(|p| p.omega).collect::<Vec<_>>()
            );
            table.prune.push(prune);
            table.quant_w.push(qw);
            table.quant_a.push(qa);
        }
        Ok(table)
    }

    /// Compute or load from the JSON cache.
    pub fn compute_cached(
        ev: &Evaluator,
        cfg: &SensitivityConfig,
        cache_path: &Path,
    ) -> Result<Self> {
        if cache_path.exists() {
            if let Ok(t) = Self::from_json(&Json::read_file(cache_path)?) {
                if t.variant == ev.reg.ir.variant && t.prune.len() == ev.reg.ir.layers.len() {
                    log::info!("sensitivity cache hit: {}", cache_path.display());
                    return Ok(t);
                }
            }
        }
        log::info!("computing sensitivity table ({} layers)...", ev.reg.ir.layers.len());
        let t = Self::compute(ev, cfg)?;
        t.to_json().write_file(cache_path)?;
        Ok(t)
    }

    /// Normalized feature vector for layer `i`: the agent-state summary of
    /// the probe series (log-scaled Ω at each probe point).
    pub fn layer_features(&self, i: usize) -> Vec<f32> {
        let series = [&self.prune[i], &self.quant_w[i], &self.quant_a[i]];
        let mut out = Vec::new();
        for s in series {
            for p in s.iter() {
                out.push(((p.omega + 1e-8).ln() as f32).clamp(-20.0, 20.0));
            }
        }
        out
    }

    /// Number of features `layer_features` emits per layer.
    pub fn feature_dim(&self) -> usize {
        if self.prune.is_empty() {
            0
        } else {
            self.prune[0].len() + self.quant_w[0].len() + self.quant_a[0].len()
        }
    }

    // ---------------- (de)serialization ----------------
    /// JSON form (the sensitivity cache file).
    pub fn to_json(&self) -> Json {
        let series = |s: &Vec<Vec<SensitivityProbe>>| {
            Json::Arr(
                s.iter()
                    .map(|layer| {
                        Json::Arr(
                            layer
                                .iter()
                                .flat_map(|p| [Json::Num(p.value), Json::Num(p.omega)])
                                .collect(),
                        )
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("variant", Json::str(self.variant.clone())),
            ("prune", series(&self.prune)),
            ("quant_w", series(&self.quant_w)),
            ("quant_a", series(&self.quant_a)),
        ])
    }

    /// Parse a cached table (inverse of `to_json`).
    pub fn from_json(j: &Json) -> Result<Self> {
        let series = |key: &str| -> Result<Vec<Vec<SensitivityProbe>>> {
            j.req_arr(key)?
                .iter()
                .map(|layer| {
                    let flat = layer
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("bad series"))?;
                    Ok(flat
                        .chunks(2)
                        .map(|c| SensitivityProbe {
                            value: c[0].as_f64().unwrap_or(0.0),
                            omega: c[1].as_f64().unwrap_or(0.0),
                        })
                        .collect())
                })
                .collect()
        };
        Ok(Self {
            variant: j.req_str("variant")?.to_string(),
            prune: series("prune")?,
            quant_w: series("quant_w")?,
            quant_a: series("quant_a")?,
        })
    }

    /// A constant-feature table (the paper's "disabled sensitivity"
    /// ablation: "for all sensitivity-based features within the agent state
    /// a constant value was set").
    pub fn disabled(num_layers: usize, cfg: &SensitivityConfig, variant: &str) -> Self {
        let flat = |values: &[f64]| {
            vec![
                values
                    .iter()
                    .map(|&v| SensitivityProbe { value: v, omega: 1.0 })
                    .collect::<Vec<_>>();
                num_layers
            ]
        };
        Self {
            variant: variant.to_string(),
            prune: flat(&cfg.prune_ratios),
            quant_w: flat(&cfg.w_bits.iter().map(|&b| b as f64).collect::<Vec<_>>()),
            quant_a: flat(&cfg.a_bits.iter().map(|&b| b as f64).collect::<Vec<_>>()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_zero_for_identical() {
        let p = vec![0.2f32, 0.3, 0.5, 0.6, 0.3, 0.1];
        assert!(kl_divergence(&p, &p, 3).abs() < 1e-9);
    }

    #[test]
    fn kl_positive_and_asymmetric() {
        let p = vec![0.8f32, 0.15, 0.05];
        let q = vec![0.1f32, 0.45, 0.45];
        let a = kl_divergence(&p, &q, 3);
        let b = kl_divergence(&q, &p, 3);
        assert!(a > 0.0 && b > 0.0);
        assert!((a - b).abs() > 1e-6);
    }

    #[test]
    fn json_roundtrip() {
        let t = SensitivityTable {
            variant: "tiny".into(),
            prune: vec![vec![SensitivityProbe { value: 0.5, omega: 0.1 }]],
            quant_w: vec![vec![SensitivityProbe { value: 4.0, omega: 0.2 }]],
            quant_a: vec![vec![SensitivityProbe { value: 2.0, omega: 0.3 }]],
        };
        let back = SensitivityTable::from_json(&t.to_json()).unwrap();
        assert_eq!(back.variant, "tiny");
        assert_eq!(back.prune[0][0], SensitivityProbe { value: 0.5, omega: 0.1 });
        assert_eq!(back.quant_a[0][0].omega, 0.3);
    }

    #[test]
    fn feature_vector_shape() {
        let cfg = SensitivityConfig::default();
        let t = SensitivityTable::disabled(3, &cfg, "tiny");
        assert_eq!(
            t.feature_dim(),
            cfg.prune_ratios.len() + cfg.w_bits.len() + cfg.a_bits.len()
        );
        let f = t.layer_features(1);
        assert_eq!(f.len(), t.feature_dim());
        // disabled table: constant features
        let g = t.layer_features(2);
        assert_eq!(f, g);
    }
}
