//! Deterministic fault injection for crash-recovery tests.
//!
//! A [`FaultPlan`] arms a fixed set of faults before a run: *"the 3rd hit
//! on site `episode` panics"*, *"the 1st hit on site `checkpoint-write`
//! fails with an IO error"*.  Production code threads a plan through its
//! options (default: empty, zero-cost) and calls [`FaultPlan::trip`] /
//! [`FaultPlan::corrupt`] at its fault sites; tests arm plans directly or
//! via the `GALEN_FAULTS` environment variable (read only at the CLI
//! boundary, [`FaultPlan::from_env`]).
//!
//! Plans are deterministic by construction: a fault fires when a site's
//! hit *count* reaches the armed threshold — no clocks, no randomness — so
//! the same plan against the same workload fires at the same point every
//! run (with a single worker, bit-reproducibly so).
//!
//! Spec syntax (`GALEN_FAULTS` and [`FaultPlan::parse`]):
//! `site[:n]:kind` entries separated by commas, where `kind` is one of
//! `panic`, `abort`, `io-error` (alias `error`), `corrupt`, and `n`
//! defaults to 1 (fire on the first hit).  Example:
//! `episode:5:abort,measure:io-error`.
//!
//! Fault sites currently armed in the codebase:
//!
//! | site               | location                              | kinds        |
//! |--------------------|---------------------------------------|--------------|
//! | `episode`          | serve worker, after an episode runs and before its checkpoint persists | panic, abort, io-error |
//! | `checkpoint-write` | serve worker, per-episode checkpoint write | io-error, panic |
//! | `checkpoint-read`  | serve worker, checkpoint load on `--resume-jobs` | corrupt, io-error |
//! | `measure`          | `hw::MeasuredProfiler`, one kernel measurement | io-error, panic |
//! | `profile-write`    | `hw::MeasuredProfiler::save` manifest write | io-error |
//! | `journal-append`   | `coordinator::ServeJournal`, between a record's write and its fsync | io-error |

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

/// What happens when an armed fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` on the calling thread (exercises `catch_unwind` isolation).
    Panic,
    /// `std::process::abort()` — a hard kill, as if the process died
    /// mid-flight (exercises journal replay / checkpoint resume).
    Abort,
    /// Return an injected error (exercises retry/backoff and degradation).
    Error,
    /// Mangle the bytes a read site just read (exercises corrupt-artifact
    /// hardening); at non-read sites it behaves like [`FaultKind::Error`].
    Corrupt,
}

impl FaultKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "panic" => Ok(Self::Panic),
            "abort" => Ok(Self::Abort),
            "error" | "io-error" => Ok(Self::Error),
            "corrupt" => Ok(Self::Corrupt),
            other => anyhow::bail!("unknown fault kind '{other}' (panic|abort|io-error|corrupt)"),
        }
    }
}

/// One armed fault: fires once, when `site`'s hit count reaches `at`.
#[derive(Debug)]
struct Armed {
    site: String,
    at: u64,
    kind: FaultKind,
    hits: AtomicU64,
}

/// A set of armed faults, shared by handle (cloning shares the counters, so
/// every component of a run observes one consistent plan).  The default
/// plan is empty and never fires.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    armed: Arc<Vec<Armed>>,
}

impl FaultPlan {
    /// The empty plan (no faults; every check is a cheap no-op).
    pub fn none() -> Self {
        Self::default()
    }

    /// Whether any fault is armed.
    pub fn is_empty(&self) -> bool {
        self.armed.is_empty()
    }

    /// Parse a comma-separated `site[:n]:kind` spec (see module docs).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut armed = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            let (site, at, kind) = match fields.as_slice() {
                [site, kind] => (*site, 1u64, FaultKind::parse(kind)?),
                [site, n, kind] => {
                    let at: u64 = n
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad fault count '{n}' in '{part}'"))?;
                    anyhow::ensure!(at >= 1, "fault count must be >= 1 in '{part}'");
                    (*site, at, FaultKind::parse(kind)?)
                }
                _ => anyhow::bail!("bad fault spec '{part}' (expected site[:n]:kind)"),
            };
            anyhow::ensure!(!site.is_empty(), "empty fault site in '{part}'");
            armed.push(Armed {
                site: site.to_string(),
                at,
                kind,
                hits: AtomicU64::new(0),
            });
        }
        Ok(Self { armed: Arc::new(armed) })
    }

    /// The plan armed by the `GALEN_FAULTS` environment variable (empty or
    /// unset = no faults).  Read this once at the CLI boundary and thread
    /// the plan explicitly — library code never touches the environment.
    pub fn from_env() -> Result<Self> {
        match std::env::var("GALEN_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Self::parse(&spec)
                .map_err(|e| e.context("parsing GALEN_FAULTS")),
            _ => Ok(Self::none()),
        }
    }

    /// Count one hit at `site`; returns the armed kind exactly when some
    /// armed fault's threshold is reached (each armed fault fires at most
    /// once).  Most callers want [`FaultPlan::trip`] instead.
    pub fn hit(&self, site: &str) -> Option<FaultKind> {
        let mut fired = None;
        for a in self.armed.iter().filter(|a| a.site == site) {
            if a.hits.fetch_add(1, Ordering::SeqCst) + 1 == a.at {
                fired = fired.or(Some(a.kind));
            }
        }
        fired
    }

    /// Count one hit at `site` and apply the consequence if a fault fires:
    /// `panic` panics, `abort` kills the process, `io-error`/`corrupt`
    /// return an injected error for the caller to handle like any other
    /// fallible operation.
    pub fn trip(&self, site: &str) -> Result<()> {
        match self.hit(site) {
            None => Ok(()),
            Some(kind) => consequence(kind, site),
        }
    }

    /// Read-site variant of [`FaultPlan::trip`]: a firing `corrupt` fault
    /// mangles `data` in place (truncates and appends garbage, so the
    /// result is never valid JSON); other kinds behave as in `trip`.
    pub fn corrupt(&self, site: &str, data: &mut String) -> Result<()> {
        match self.hit(site) {
            None => Ok(()),
            Some(FaultKind::Corrupt) => {
                data.truncate(data.len() / 2);
                data.push_str("\u{0}garbage{{{");
                Ok(())
            }
            Some(kind) => consequence(kind, site),
        }
    }
}

fn consequence(kind: FaultKind, site: &str) -> Result<()> {
    match kind {
        FaultKind::Panic => panic!("injected fault: panic at site '{site}'"),
        FaultKind::Abort => {
            // eprint (not log) so the kill is visible even without a logger
            eprintln!("injected fault: abort at site '{site}'");
            std::process::abort();
        }
        FaultKind::Error | FaultKind::Corrupt => {
            anyhow::bail!("injected fault: io error at site '{site}'")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_forms_and_defaults() {
        let p = FaultPlan::parse("episode:5:abort, measure:io-error,ckpt:2:corrupt").unwrap();
        assert!(!p.is_empty());
        // measure defaults to n=1: the very first hit fires
        assert_eq!(p.hit("measure"), Some(FaultKind::Error));
        assert_eq!(p.hit("measure"), None, "each armed fault fires once");
        // episode fires on the 5th hit only
        for _ in 0..4 {
            assert_eq!(p.hit("episode"), None);
        }
        assert_eq!(p.hit("episode"), Some(FaultKind::Abort));
        assert_eq!(p.hit("episode"), None);
        // unknown sites never fire
        assert_eq!(p.hit("nope"), None);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("episode:zero:panic").is_err());
        assert!(FaultPlan::parse("episode:0:panic").is_err(), "counts are 1-based");
        assert!(FaultPlan::parse("episode:1:explode").is_err());
        assert!(FaultPlan::parse("justasite").is_err());
        assert!(FaultPlan::parse(":1:panic").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn clones_share_counters() {
        let p = FaultPlan::parse("s:2:io-error").unwrap();
        let q = p.clone();
        assert_eq!(p.hit("s"), None);
        assert_eq!(q.hit("s"), Some(FaultKind::Error), "clone sees the first hit");
    }

    #[test]
    fn trip_returns_injected_error() {
        let p = FaultPlan::parse("w:1:io-error").unwrap();
        let e = p.trip("w").unwrap_err();
        assert!(format!("{e:#}").contains("injected fault"), "{e:#}");
        p.trip("w").unwrap();
    }

    #[test]
    #[should_panic(expected = "injected fault: panic at site 'boom'")]
    fn trip_panics_on_panic_kind() {
        FaultPlan::parse("boom:panic").unwrap().trip("boom").unwrap();
    }

    #[test]
    fn corrupt_mangles_read_data() {
        let p = FaultPlan::parse("r:1:corrupt").unwrap();
        let mut s = r#"{"ok": true}"#.to_string();
        p.corrupt("r", &mut s).unwrap();
        assert!(crate::util::json::Json::parse(&s).is_err(), "mangled: {s}");
        // second hit: untouched
        let mut t = "[1]".to_string();
        p.corrupt("r", &mut t).unwrap();
        assert_eq!(t, "[1]");
    }

    #[test]
    fn empty_plan_is_free_of_consequences() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        p.trip("anything").unwrap();
        let mut s = "x".to_string();
        p.corrupt("anything", &mut s).unwrap();
        assert_eq!(s, "x");
    }
}
