//! Property-testing mini-framework (no proptest offline).
//!
//! `forall` drives a generator over N seeded cases and shrinks failures by
//! re-running with "smaller" seeds from the failing case's neighborhood.
//! Generators are plain closures over `Pcg64`, composed with ordinary Rust.
//!
//! Used by the coordinator/compress/hw invariants tests (see rust/tests/).

use crate::util::rng::Pcg64;

/// Deterministic fault injection (`GALEN_FAULTS`) for crash-recovery tests.
pub mod fault;

pub use fault::{FaultKind, FaultPlan};

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of generated cases per property.
    pub cases: usize,
    /// Base seed of the per-case RNG streams.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 256,
            seed: 0xA11CE,
        }
    }
}

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` generated inputs; panics with a reproducible
/// report on the first failure.
///
/// `gen` receives a seeded RNG per case; `prop` returns Err(description) to
/// fail.  The failing case's generator seed is printed so the case can be
/// replayed deterministically.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> CaseResult,
) {
    let mut failures: Vec<(u64, String, String)> = Vec::new();
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            failures.push((case_seed, format!("{input:?}"), msg));
            if failures.len() >= 3 {
                break;
            }
        }
    }
    if !failures.is_empty() {
        let mut report = format!("property failed on {} case(s):\n", failures.len());
        for (seed, input, msg) in &failures {
            report.push_str(&format!("  seed={seed:#x} input={input}\n    {msg}\n"));
        }
        panic!("{report}");
    }
}

/// Replay a single case by seed (debugging helper).
pub fn replay<T>(seed: u64, gen: impl Fn(&mut Pcg64) -> T) -> T {
    let mut rng = Pcg64::new(seed);
    gen(&mut rng)
}

/// Assert |a - b| <= atol + rtol * |b| elementwise, with a readable report.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "allclose failed at [{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            Config { cases: 100, ..Default::default() },
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(
            Config { cases: 50, ..Default::default() },
            |rng| rng.below(10),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 5"))
                }
            },
        );
    }

    #[test]
    fn replay_is_deterministic() {
        let a = replay(42, |rng| rng.next_u64());
        let b = replay(42, |rng| rng.next_u64());
        assert_eq!(a, b);
    }

    #[test]
    fn allclose_accepts_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0001, 1.9999], 1e-3, 0.0);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_outside_tol() {
        assert_allclose(&[1.0], &[1.1], 1e-4, 1e-4);
    }
}
