//! Quantization-method selection (paper §Quantization Implementation
//! Details): thresholds over the predicted actions pick FP32 / INT8 / MIX,
//! and Eq. 8 rescales the action into the MIX compression parameter.

/// MIX threshold t_mix (paper: 0.5).
pub const T_MIX: f64 = 0.5;
/// INT8 threshold t_int8 (paper: 0.2).
pub const T_INT8: f64 = 0.2;

/// The quantization mode of one layer after discretization.
/// (`Hash` lets the hardware simulator memoize per-layer costs keyed by
/// layer configuration — see `hw::LatencySimulator`.)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantMode {
    /// No quantization (single-precision float).
    Fp32,
    /// Fixed-point 8-bit integer quantization.
    Int8,
    /// Mixed precision with independent weight/activation bit widths.
    Mix { w_bits: u8, a_bits: u8 },
}

impl QuantMode {
    /// Effective (weight, activation) bit widths for BOPs accounting.
    pub fn bits(&self) -> (u32, u32) {
        match self {
            QuantMode::Fp32 => (32, 32),
            QuantMode::Int8 => (8, 8),
            QuantMode::Mix { w_bits, a_bits } => (*w_bits as u32, *a_bits as u32),
        }
    }

    /// Runtime policy scalars for the artifact (0 = bypass/FP32).
    pub fn policy_bits(&self) -> (f32, f32) {
        match self {
            QuantMode::Fp32 => (0.0, 0.0),
            QuantMode::Int8 => (8.0, 8.0),
            QuantMode::Mix { w_bits, a_bits } => (*w_bits as f32, *a_bits as f32),
        }
    }

    /// Whether this is the bit-serial MIX mode.
    pub fn is_mix(&self) -> bool {
        matches!(self, QuantMode::Mix { .. })
    }

    /// Number of distinct [`QuantMode::class_id`] values — the size of any
    /// array indexed by mode class (hybrid calibration, profiler fallback).
    pub const CLASSES: usize = 3;

    /// Stable discriminant of the mode *class* (FP32 / INT8 / MIX): shared
    /// by the simulator's measurement-noise streams, the profiler's cache
    /// keys, and the hybrid calibration classes, so those keyed structures
    /// cannot classify the same mode differently.  MIX bit widths are
    /// deliberately excluded — combine with `bits()` where they matter.
    pub fn class_id(&self) -> u64 {
        match self {
            QuantMode::Fp32 => 0,
            QuantMode::Int8 => 1,
            QuantMode::Mix { .. } => 2,
        }
    }

    /// Human-readable label (`FP32`, `INT8`, `MIX(w3/a5)`).
    pub fn label(&self) -> String {
        match self {
            QuantMode::Fp32 => "FP32".into(),
            QuantMode::Int8 => "INT8".into(),
            QuantMode::Mix { w_bits, a_bits } => format!("MIX(w{w_bits}/a{a_bits})"),
        }
    }
}

/// Eq. 8: rescale action above t_mix into the MIX compression ratio r.
/// (The paper's printed min/max order is swapped; the intended clamp to
/// [0, 1] is used here.)
fn mix_ratio(action: f64) -> f64 {
    ((action - T_MIX) / (1.0 - T_MIX)).clamp(0.0, 1.0)
}

/// Eq. 4 applied to bit widths: ratio r -> discrete bits in [1, max_bits].
fn mix_bits(r: f64, max_bits: u8) -> u8 {
    (((1.0 - r) * max_bits as f64).floor() as i64 + 1).clamp(1, max_bits as i64) as u8
}

/// Map the (activation, weight) quantization actions of a layer to a mode.
///
/// Paper: if either action exceeds t_mix => MIX (falling back to INT8 where
/// unsupported); else if either exceeds t_int8 => INT8; else FP32.
/// `max_bits` limits the MIX exploration range (paper uses 6: bit-serial
/// beyond 6 bits is slower than INT8 on the target).
pub fn select_quant_mode(
    a_act: f64,
    a_weight: f64,
    mix_supported: bool,
    max_bits: u8,
) -> QuantMode {
    debug_assert!((0.0..=1.0).contains(&a_act) && (0.0..=1.0).contains(&a_weight));
    if a_act > T_MIX || a_weight > T_MIX {
        if mix_supported {
            let r_a = mix_ratio(a_act);
            let r_w = mix_ratio(a_weight);
            return QuantMode::Mix {
                w_bits: mix_bits(r_w, max_bits),
                a_bits: mix_bits(r_a, max_bits),
            };
        }
        return QuantMode::Int8;
    }
    if a_act > T_INT8 || a_weight > T_INT8 {
        return QuantMode::Int8;
    }
    QuantMode::Fp32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds() {
        assert_eq!(select_quant_mode(0.1, 0.1, true, 6), QuantMode::Fp32);
        assert_eq!(select_quant_mode(0.3, 0.1, true, 6), QuantMode::Int8);
        assert_eq!(select_quant_mode(0.1, 0.25, true, 6), QuantMode::Int8);
        assert!(select_quant_mode(0.7, 0.7, true, 6).is_mix());
        // MIX unsupported falls back to INT8, never FP32
        assert_eq!(select_quant_mode(0.9, 0.9, false, 6), QuantMode::Int8);
    }

    #[test]
    fn mix_bit_mapping_monotone() {
        // stronger action (closer to 1) => fewer bits
        let bits =
            |a: f64| match select_quant_mode(a, a, true, 6) {
                QuantMode::Mix { w_bits, .. } => w_bits,
                m => panic!("expected mix, got {m:?}"),
            };
        let mut prev = 7;
        for a in [0.55, 0.65, 0.75, 0.85, 0.95, 1.0] {
            let b = bits(a);
            assert!(b <= prev, "a={a} bits={b} prev={prev}");
            assert!((1..=6).contains(&b));
            prev = b;
        }
        assert_eq!(bits(1.0), 1); // max action => 1 bit
        assert_eq!(bits(0.5 + 1e-9), 6); // just over threshold => max bits
    }

    #[test]
    fn independent_w_a_bits() {
        match select_quant_mode(0.6, 0.95, true, 6) {
            QuantMode::Mix { w_bits, a_bits } => {
                assert!(w_bits < a_bits, "w={w_bits} a={a_bits}");
            }
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn bops_bits() {
        assert_eq!(QuantMode::Fp32.bits(), (32, 32));
        assert_eq!(QuantMode::Int8.bits(), (8, 8));
        assert_eq!(QuantMode::Mix { w_bits: 3, a_bits: 5 }.bits(), (3, 5));
    }

    #[test]
    fn policy_bits_bypass_semantics() {
        assert_eq!(QuantMode::Fp32.policy_bits(), (0.0, 0.0));
        assert_eq!(QuantMode::Int8.policy_bits(), (8.0, 8.0));
    }

    #[test]
    fn max_bits_respected() {
        for a in [0.51, 0.7, 0.99] {
            if let QuantMode::Mix { w_bits, a_bits } = select_quant_mode(a, a, true, 4) {
                assert!(w_bits <= 4 && a_bits <= 4);
            }
        }
    }
}
