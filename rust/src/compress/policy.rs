//! Policy representations along the mapping chain, and the ℓ1 channel
//! ranking (Li et al. 2017) that picks *which* channels a pruning decision
//! removes.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::quant_mode::QuantMode;
use crate::model::{LayerKind, ModelIr};
use crate::util::json::Json;

/// Continuous per-layer compression parameters r (paper Eq. 1): one entry
/// per layer per method, all in [0, 1].  Kept for logging/analysis; the
/// agents map actions straight to `DiscretePolicy`.
#[derive(Clone, Debug, Default)]
pub struct ContinuousPolicy {
    /// layer index -> pruning ratio r (0 = keep all).
    pub prune: BTreeMap<usize, f64>,
    /// layer index -> (activation action, weight action).
    pub quant: BTreeMap<usize, (f64, f64)>,
}

/// Discrete compression parameters of one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerCmp {
    /// Output channels kept (== original width when unpruned).
    pub kept_channels: usize,
    /// Quantization mode of the layer.
    pub quant: QuantMode,
}

impl LayerCmp {
    /// Serialize one layer decision (`channels`, `mode`, `w_bits`,
    /// `a_bits`) — the per-layer entry of sweep artifacts and driver
    /// checkpoints.
    pub fn to_json(&self) -> Json {
        let (wb, ab) = self.quant.bits();
        Json::obj(vec![
            ("channels", Json::num(self.kept_channels as f64)),
            ("mode", Json::str(mode_tag(self.quant))),
            ("w_bits", Json::num(wb as f64)),
            ("a_bits", Json::num(ab as f64)),
        ])
    }

    /// Rebuild a decision serialized by [`LayerCmp::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        let wb = j.req_f64("w_bits")? as u32;
        let ab = j.req_f64("a_bits")? as u32;
        let quant = match j.req_str("mode")? {
            "fp32" => QuantMode::Fp32,
            "int8" => QuantMode::Int8,
            "mix" => QuantMode::Mix {
                w_bits: wb as u8,
                a_bits: ab as u8,
            },
            other => bail!("unknown quant mode '{other}'"),
        };
        Ok(Self {
            kept_channels: j.req_usize("channels")?,
            quant,
        })
    }
}

/// Stable artifact tag of a quant mode class (`fp32`/`int8`/`mix`).
fn mode_tag(q: QuantMode) -> &'static str {
    match q {
        QuantMode::Fp32 => "fp32",
        QuantMode::Int8 => "int8",
        QuantMode::Mix { .. } => "mix",
    }
}

/// A complete discrete compression policy: one `LayerCmp` per IR layer.
#[derive(Clone, Debug, PartialEq)]
pub struct DiscretePolicy {
    /// One compression decision per IR layer, in layer order.
    pub layers: Vec<LayerCmp>,
}

impl DiscretePolicy {
    /// The reference policy P_r: no pruning, no quantization.
    pub fn reference(ir: &ModelIr) -> Self {
        Self {
            layers: ir
                .layers
                .iter()
                .map(|l| LayerCmp {
                    kept_channels: l.cout,
                    quant: QuantMode::Fp32,
                })
                .collect(),
        }
    }

    /// Effective input channels of layer `i` after pruning of its producers:
    /// conv1 layers read the (unpruned) residual stream; conv2 reads its
    /// block's conv1 (MobileNet: dw reads its expand, project its dw).
    /// Uses the IR consumer wiring in reverse via `ModelIr::producer_of`.
    pub fn effective_cin(&self, ir: &ModelIr, i: usize) -> usize {
        match ir.producer_of(i) {
            Some(p) => self.layers[p].kept_channels,
            None => ir.layers[i].cin,
        }
    }

    /// Total MACs under this policy (pruning-aware; per sample).
    pub fn macs(&self, ir: &ModelIr) -> u64 {
        ir.layers
            .iter()
            .map(|l| {
                let cin = self.effective_cin(ir, l.index);
                l.macs_at(cin, self.layers[l.index].kept_channels)
            })
            .sum()
    }

    /// Total BOPs (paper: MACs x w_bits x a_bits) under this policy.
    pub fn bops(&self, ir: &ModelIr) -> u64 {
        ir.layers
            .iter()
            .map(|l| {
                let cin = self.effective_cin(ir, l.index);
                let macs = l.macs_at(cin, self.layers[l.index].kept_channels);
                let (wb, ab) = self.layers[l.index].quant.bits();
                macs * wb as u64 * ab as u64
            })
            .sum()
    }

    /// Parameter count under this policy.
    pub fn params(&self, ir: &ModelIr) -> u64 {
        ir.layers
            .iter()
            .map(|l| {
                let cin = self.effective_cin(ir, l.index);
                l.params_at(cin, self.layers[l.index].kept_channels)
            })
            .sum()
    }

    /// Serialize the policy as an array of per-layer decisions (the
    /// `policy` field of sweep artifacts and driver checkpoints).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.layers.iter().map(|l| l.to_json()).collect())
    }

    /// Rebuild a policy serialized by [`DiscretePolicy::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("policy json is not an array"))?;
        let layers = arr.iter().map(LayerCmp::from_json).collect::<Result<Vec<_>>>()?;
        Ok(Self { layers })
    }

    /// Human-readable per-layer summary (Fig 3 style).
    pub fn describe(&self, ir: &ModelIr) -> String {
        let mut s = String::new();
        for l in &ir.layers {
            let c = &self.layers[l.index];
            s.push_str(&format!(
                "{:14} {:>4}/{:<4} {}\n",
                l.name,
                c.kept_channels,
                l.cout,
                c.quant.label()
            ));
        }
        s
    }
}

/// Flattened runtime policy inputs for the PJRT artifact, in policy-manifest
/// order (mask vectors and bit scalars).
#[derive(Clone, Debug)]
pub struct PolicyInputs {
    /// One flat f32 buffer per policy-manifest entry.
    pub buffers: Vec<Vec<f32>>,
}

/// ℓ1 ranking of output channels: indices sorted by *descending* ℓ1 norm
/// (keep-first order).  `w` is the flat weight tensor, `shape` its dims with
/// the output-channel axis last (HWIO conv / [in, out] linear).
pub fn l1_channel_ranking(w: &[f32], shape: &[usize]) -> Vec<usize> {
    let cout = *shape.last().expect("empty shape");
    assert_eq!(w.len() % cout, 0);
    let mut norms = vec![0.0f64; cout];
    for (i, &x) in w.iter().enumerate() {
        norms[i % cout] += x.abs() as f64;
    }
    let mut idx: Vec<usize> = (0..cout).collect();
    idx.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
    idx
}

/// Precomputed ℓ1 keep-first channel rankings per conv layer (weights are
/// fixed during a search, so rankings are computed once — §Perf).
pub fn precompute_rankings(
    ir: &ModelIr,
    weights_by_name: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
) -> BTreeMap<String, Vec<usize>> {
    let mut out = BTreeMap::new();
    for l in &ir.layers {
        if l.kind == LayerKind::Conv {
            if let Some((shape, w)) = weights_by_name.get(&format!("{}.w", l.name)) {
                out.insert(l.name.clone(), l1_channel_ranking(w, shape));
            }
        }
    }
    out
}

impl PolicyInputs {
    /// Build the runtime inputs for `policy`.
    ///
    /// `weights_by_name` supplies the conv/fc weight tensors for the ℓ1
    /// strategy; pass the loaded `weights_<variant>.gten` map.  Masks keep
    /// the `kept_channels` channels of largest ℓ1 norm (paper: "identify the
    /// channels with least magnitude weights and remove them").
    pub fn build(
        ir: &ModelIr,
        policy: &DiscretePolicy,
        weights_by_name: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) -> Result<Self> {
        let rankings = precompute_rankings(ir, weights_by_name);
        Self::build_with_rankings(ir, policy, &rankings)
    }

    /// Hot-path variant with precomputed rankings (see `precompute_rankings`).
    pub fn build_with_rankings(
        ir: &ModelIr,
        policy: &DiscretePolicy,
        rankings: &BTreeMap<String, Vec<usize>>,
    ) -> Result<Self> {
        if policy.layers.len() != ir.layers.len() {
            bail!(
                "policy has {} layers, model {}",
                policy.layers.len(),
                ir.layers.len()
            );
        }
        let mut buffers = vec![Vec::new(); ir.policy_index.len()];
        for l in &ir.layers {
            let cmp = &policy.layers[l.index];
            if cmp.kept_channels == 0 || cmp.kept_channels > l.cout {
                bail!("{}: kept_channels {} out of range", l.name, cmp.kept_channels);
            }
            let (wb, ab) = cmp.quant.policy_bits();
            if l.kind == LayerKind::Conv {
                let mask_pos = ir
                    .policy_pos(&format!("{}.mask", l.name))
                    .ok_or_else(|| anyhow::anyhow!("no mask input for {}", l.name))?;
                let mut mask = vec![0.0f32; l.cout];
                if cmp.kept_channels == l.cout {
                    mask.fill(1.0);
                } else {
                    let ranking = rankings
                        .get(&l.name)
                        .ok_or_else(|| anyhow::anyhow!("missing ranking for {}", l.name))?;
                    for &c in ranking.iter().take(cmp.kept_channels) {
                        mask[c] = 1.0;
                    }
                }
                buffers[mask_pos] = mask;
            }
            let wpos = ir
                .policy_pos(&format!("{}.w_bits", l.name))
                .ok_or_else(|| anyhow::anyhow!("no w_bits input for {}", l.name))?;
            let apos = ir
                .policy_pos(&format!("{}.a_bits", l.name))
                .ok_or_else(|| anyhow::anyhow!("no a_bits input for {}", l.name))?;
            buffers[wpos] = vec![wb];
            buffers[apos] = vec![ab];
        }
        Ok(Self { buffers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelIr;

    fn ir() -> ModelIr {
        ModelIr::from_meta(&crate::model::ir::test_fixtures::tiny_meta()).unwrap()
    }

    fn weights_for(ir: &ModelIr) -> BTreeMap<String, (Vec<usize>, Vec<f32>)> {
        let mut m = BTreeMap::new();
        for l in &ir.layers {
            let shape = match l.kind {
                LayerKind::Conv => vec![l.kernel, l.kernel, l.cin, l.cout],
                LayerKind::Linear => vec![l.cin, l.cout],
            };
            let n: usize = shape.iter().product();
            // deterministic weights: channel c has magnitude ~ c+1 so the ℓ1
            // ranking is the identity reversed (largest channel index first)
            let cout = l.cout;
            let w: Vec<f32> = (0..n).map(|i| (i % cout) as f32 + 1.0).collect();
            m.insert(format!("{}.w", l.name), (shape, w));
        }
        m
    }

    #[test]
    fn reference_policy_counts() {
        let ir = ir();
        let p = DiscretePolicy::reference(&ir);
        assert_eq!(p.macs(&ir), ir.total_macs());
        assert_eq!(p.bops(&ir), ir.total_macs() * 32 * 32);
        assert_eq!(p.params(&ir), ir.total_params());
    }

    #[test]
    fn pruning_shrinks_consumer_macs() {
        let ir = ir();
        let mut p = DiscretePolicy::reference(&ir);
        // prune s0b0.conv1 (index 1) to half
        p.layers[1].kept_channels = 4;
        let conv2 = &ir.layers[2];
        assert_eq!(p.effective_cin(&ir, 2), 4);
        let macs = p.macs(&ir);
        let expect_delta = conv2.macs() - conv2.macs_at(4, conv2.cout)
            + (ir.layers[1].macs() - ir.layers[1].macs_at(ir.layers[1].cin, 4));
        assert_eq!(ir.total_macs() - macs, expect_delta);
    }

    #[test]
    fn quant_shrinks_bops_not_macs() {
        let ir = ir();
        let mut p = DiscretePolicy::reference(&ir);
        p.layers[0].quant = QuantMode::Int8;
        assert_eq!(p.macs(&ir), ir.total_macs());
        assert!(p.bops(&ir) < ir.total_macs() * 32 * 32);
    }

    #[test]
    fn l1_ranking_orders_by_magnitude() {
        // 2 channels: channel 1 bigger
        let w = vec![1.0, 10.0, 1.0, 10.0]; // shape [2, 2] (in, out)
        assert_eq!(l1_channel_ranking(&w, &[2, 2]), vec![1, 0]);
        // negative magnitudes count via |.|
        let w = vec![-5.0, 1.0, -5.0, 1.0];
        assert_eq!(l1_channel_ranking(&w, &[2, 2]), vec![0, 1]);
    }

    #[test]
    fn policy_inputs_layout() {
        let ir = ir();
        let weights = weights_for(&ir);
        let mut p = DiscretePolicy::reference(&ir);
        p.layers[1].kept_channels = 4; // prune conv1 to 4 of 8
        p.layers[3].quant = QuantMode::Mix {
            w_bits: 3,
            a_bits: 5,
        };
        p.layers[6].quant = QuantMode::Int8;
        let inputs = PolicyInputs::build(&ir, &p, &weights).unwrap();
        assert_eq!(inputs.buffers.len(), ir.policy_index.len());
        // mask of layer 1 has exactly 4 ones, on the largest-ℓ1 channels (4..8)
        let mask = &inputs.buffers[ir.policy_pos("s0b0.conv1.mask").unwrap()];
        assert_eq!(mask.iter().filter(|&&x| x == 1.0).count(), 4);
        assert_eq!(&mask[4..], &[1.0, 1.0, 1.0, 1.0]);
        // bit scalars
        assert_eq!(
            inputs.buffers[ir.policy_pos("s1b0.conv1.w_bits").unwrap()],
            vec![3.0]
        );
        assert_eq!(
            inputs.buffers[ir.policy_pos("s1b0.conv1.a_bits").unwrap()],
            vec![5.0]
        );
        assert_eq!(
            inputs.buffers[ir.policy_pos("fc.w_bits").unwrap()],
            vec![8.0]
        );
        // unpruned conv masks are all ones
        let stem_mask = &inputs.buffers[ir.policy_pos("stem.mask").unwrap()];
        assert!(stem_mask.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn policy_inputs_rejects_bad_channels() {
        let ir = ir();
        let weights = weights_for(&ir);
        let mut p = DiscretePolicy::reference(&ir);
        p.layers[0].kept_channels = 0;
        assert!(PolicyInputs::build(&ir, &p, &weights).is_err());
    }

    #[test]
    fn policy_json_roundtrip_all_modes() {
        let mut p = DiscretePolicy {
            layers: vec![
                LayerCmp { kept_channels: 7, quant: QuantMode::Fp32 },
                LayerCmp { kept_channels: 3, quant: QuantMode::Int8 },
                LayerCmp {
                    kept_channels: 64,
                    quant: QuantMode::Mix { w_bits: 3, a_bits: 5 },
                },
            ],
        };
        let back =
            DiscretePolicy::from_json(&crate::util::json::Json::parse(&p.to_json().dump()).unwrap())
                .unwrap();
        assert_eq!(back, p);
        p.layers[0].quant = QuantMode::Int8;
        assert_ne!(back, p);
        assert!(DiscretePolicy::from_json(&crate::util::json::Json::Num(1.0)).is_err());
    }

    #[test]
    fn describe_contains_layers() {
        let ir = ir();
        let p = DiscretePolicy::reference(&ir);
        let d = p.describe(&ir);
        assert!(d.contains("stem") && d.contains("fc") && d.contains("FP32"));
    }
}
