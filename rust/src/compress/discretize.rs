//! Eq. 4 inverse mapping from continuous compression ratios to discrete
//! CMPs, plus the hardware-motivated channel rounding (bit-serial operators
//! need channel multiples of 32/8 — paper §Direct Metric).

/// Options controlling the ratio -> channel-count mapping of one layer.
#[derive(Clone, Copy, Debug)]
pub struct DiscretizeOpts {
    /// Round the kept-channel count up to a multiple (e.g. 32 for joint
    /// agents so pruned layers stay MIX-compatible). 1 = no rounding.
    pub channel_multiple: usize,
    /// Lower bound on kept channels (>= 1).
    pub min_channels: usize,
}

impl Default for DiscretizeOpts {
    fn default() -> Self {
        Self {
            channel_multiple: 1,
            min_channels: 1,
        }
    }
}

/// Round `x` up to a multiple of `m` (m >= 1).
pub fn round_to_multiple(x: usize, m: usize) -> usize {
    if m <= 1 {
        return x;
    }
    x.div_ceil(m) * m
}

/// Eq. 4: d_v(r) = floor((1 - r) * v) + 1, then hardware rounding.
///
/// `r` is the compression ratio in [0, 1] (0 = keep everything), `v` the
/// reference (original channel count).  Returns the kept channel count in
/// [min_channels.., v].
pub fn discretize(r: f64, v: usize, opts: DiscretizeOpts) -> usize {
    let r = r.clamp(0.0, 1.0);
    let base = ((1.0 - r) * v as f64).floor() as usize + 1;
    let base = base.min(v).max(opts.min_channels);
    round_to_multiple(base, opts.channel_multiple).min(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_zero() {
        // r=0 keeps all channels: floor(1*64)+1 = 65 clamped to 64
        assert_eq!(discretize(0.0, 64, DiscretizeOpts::default()), 64);
    }

    #[test]
    fn max_compression_keeps_one() {
        assert_eq!(discretize(1.0, 64, DiscretizeOpts::default()), 1);
    }

    #[test]
    fn monotone_nonincreasing_in_r() {
        let mut prev = usize::MAX;
        for i in 0..=100 {
            let r = i as f64 / 100.0;
            let c = discretize(r, 128, DiscretizeOpts::default());
            assert!(c <= prev, "r={r} c={c} prev={prev}");
            assert!((1..=128).contains(&c));
            prev = c;
        }
    }

    #[test]
    fn channel_rounding_to_32() {
        let opts = DiscretizeOpts {
            channel_multiple: 32,
            min_channels: 1,
        };
        // any ratio lands on {32, 64, 96, ...}
        for i in 0..=20 {
            let c = discretize(i as f64 / 20.0, 256, opts);
            assert_eq!(c % 32, 0, "c={c}");
            assert!(c >= 32 && c <= 256);
        }
        // small layers cannot round above their width
        assert_eq!(discretize(0.9, 32, opts), 32);
    }

    #[test]
    fn min_channels_respected() {
        let opts = DiscretizeOpts {
            channel_multiple: 1,
            min_channels: 4,
        };
        assert_eq!(discretize(1.0, 64, opts), 4);
    }

    #[test]
    fn covers_full_range() {
        // Eq.4 must be able to reach every channel count for m=1
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..=4000 {
            seen.insert(discretize(i as f64 / 4000.0, 16, DiscretizeOpts::default()));
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn round_to_multiple_basics() {
        assert_eq!(round_to_multiple(1, 32), 32);
        assert_eq!(round_to_multiple(32, 32), 32);
        assert_eq!(round_to_multiple(33, 32), 64);
        assert_eq!(round_to_multiple(7, 1), 7);
        assert_eq!(round_to_multiple(0, 8), 0);
    }
}
