//! Compression policies and their mapping chain (paper Eqs. 1, 4, 8):
//!
//! agent action `a ∈ [0,1]^N`  →  continuous compression parameters `r`
//! →  discrete, hardware-specific CMPs (channel counts, bit widths)
//! →  runtime policy inputs (masks + bit scalars) for the PJRT artifact.

mod discretize;
mod policy;
mod quant_mode;

pub use discretize::{discretize, round_to_multiple, DiscretizeOpts};
pub use policy::{
    l1_channel_ranking, precompute_rankings, ContinuousPolicy, DiscretePolicy, LayerCmp,
    PolicyInputs,
};
pub use quant_mode::{select_quant_mode, QuantMode, T_INT8, T_MIX};
