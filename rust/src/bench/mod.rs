//! Mini-criterion: the benchmark harness behind `cargo bench` (criterion is
//! not available offline).
//!
//! Two kinds of benches share it:
//! * microbenches (`Bencher::iter`) — warmup, adaptive iteration count,
//!   mean/median/p95 over wall-clock samples;
//! * experiment harnesses (paper tables/figures) — long-running RL searches
//!   that print the paper's rows; they use `Bencher::once` so `cargo bench`
//!   drives them uniformly.

use std::time::{Duration, Instant};

/// Raw samples and derived statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark name.
    pub name: String,
    /// Wall-clock nanoseconds per sample (each sample batches iterations).
    pub samples: Vec<f64>,
    /// Iterations batched into each sample.
    pub iters_per_sample: u64,
}

impl BenchStats {
    /// Mean nanoseconds per iteration.
    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples) / self.iters_per_sample as f64
    }
    /// Median nanoseconds per iteration.
    pub fn median_ns(&self) -> f64 {
        crate::util::stats::median(&self.samples) / self.iters_per_sample as f64
    }
    /// 95th-percentile nanoseconds per iteration.
    pub fn p95_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples, 95.0) / self.iters_per_sample as f64
    }

    /// One formatted stats row (pairs with `Bencher::header`).
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12} {:>12} {:>12}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns())
        )
    }
}

/// Human-readable duration from nanoseconds (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// The harness driver: collects `BenchStats` per bench and serializes
/// them (`write_json`).
pub struct Bencher {
    /// Samples per microbench.
    pub sample_count: usize,
    /// Wall-clock target per sample (iterations batch up to this).
    pub target_sample_time: Duration,
    /// Warmup/calibration budget before sampling.
    pub warmup: Duration,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Default settings (20 samples, 100 ms target per sample).
    pub fn new() -> Self {
        Self {
            sample_count: 20,
            target_sample_time: Duration::from_millis(100),
            warmup: Duration::from_millis(200),
            results: Vec::new(),
        }
    }

    /// Cheaper settings for CI and experiment harnesses.
    pub fn fast() -> Self {
        Self {
            sample_count: 10,
            target_sample_time: Duration::from_millis(30),
            warmup: Duration::from_millis(50),
            results: Vec::new(),
        }
    }

    /// Microbench: measures `f` with warmup + adaptive batching.
    pub fn iter<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchStats {
        // warmup + calibration
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.target_sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = BenchStats {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Experiment harness: run once, report wall time.
    pub fn once<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed();
        let stats = BenchStats {
            name: name.to_string(),
            samples: vec![dt.as_nanos() as f64],
            iters_per_sample: 1,
        };
        println!("{:40} completed in {}", name, fmt_ns(dt.as_nanos() as f64));
        self.results.push(stats);
        r
    }

    /// Print the column header `report` rows align with.
    pub fn header() {
        println!(
            "{:40} {:>12} {:>12} {:>12}",
            "benchmark", "median", "mean", "p95"
        );
        println!("{}", "-".repeat(80));
    }

    /// Stats of every bench run so far, in order.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Machine-readable results: `{"meta": {...}, "benches": {name ->
    /// {ns_per_iter (p50), p95_ns, mean_ns, ...}}}`.  `meta` entries record
    /// run provenance (e.g. which IR a bench actually used) so the perf
    /// trajectory across PRs is comparable.  hot_paths writes this to
    /// `BENCH_hot_paths.json` at the repo root.
    pub fn write_json(
        &self,
        path: &std::path::Path,
        meta: &[(&str, String)],
    ) -> anyhow::Result<()> {
        use crate::util::json::Json;
        use std::collections::BTreeMap;

        let mut benches = BTreeMap::new();
        for s in &self.results {
            let mut e = BTreeMap::new();
            e.insert("ns_per_iter".to_string(), Json::num(s.median_ns()));
            e.insert("p50_ns".to_string(), Json::num(s.median_ns()));
            e.insert("p95_ns".to_string(), Json::num(s.p95_ns()));
            e.insert("mean_ns".to_string(), Json::num(s.mean_ns()));
            e.insert(
                "iters_per_sample".to_string(),
                Json::num(s.iters_per_sample as f64),
            );
            e.insert("samples".to_string(), Json::num(s.samples.len() as f64));
            benches.insert(s.name.clone(), Json::Obj(e));
        }
        let mut m = BTreeMap::new();
        for (k, v) in meta {
            m.insert((*k).to_string(), Json::str(v.clone()));
        }
        let mut root = BTreeMap::new();
        root.insert("meta".to_string(), Json::Obj(m));
        root.insert("benches".to_string(), Json::Obj(benches));
        Json::Obj(root).write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbench_produces_stats() {
        let mut b = Bencher {
            sample_count: 3,
            target_sample_time: Duration::from_micros(200),
            warmup: Duration::from_micros(200),
            results: Vec::new(),
        };
        let s = b.iter("noop-ish", || std::hint::black_box(1 + 1));
        assert_eq!(s.samples.len(), 3);
        assert!(s.mean_ns() >= 0.0);
        assert!(s.p95_ns() >= s.median_ns() * 0.5);
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bencher::fast();
        let v = b.once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn write_json_roundtrips() {
        use crate::util::json::Json;
        let mut b = Bencher::fast();
        b.once("unit/compute", || 1 + 1);
        let path = std::env::temp_dir().join("galen_bench_write_json_test.json");
        b.write_json(&path, &[("ir", "tiny".to_string())]).unwrap();
        let j = Json::read_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(j.req("meta").unwrap().req_str("ir").unwrap(), "tiny");
        let benches = j.req("benches").unwrap();
        let e = benches.req("unit/compute").unwrap();
        assert!(e.req_f64("ns_per_iter").unwrap() >= 0.0);
        assert!(e.req_f64("p95_ns").unwrap() >= 0.0);
    }
}
