//! Minimal timestamped stderr logger backing the `log` crate facade.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; respects `GALEN_LOG` (error|warn|info|debug|trace).
pub fn init(default_level: LevelFilter) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("GALEN_LOG").ok().as_deref() {
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("info") => LevelFilter::Info,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ => default_level,
    };
    let logger = Box::leak(Box::new(StderrLogger {
        start: Instant::now(),
    }));
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Info);
        init(LevelFilter::Debug); // second call must not panic
        log::info!("logging smoke test");
    }
}
