//! Minimal timestamped stderr logger backing the `log` crate facade,
//! with a thread-local context tag for worker threads.
//!
//! The serve worker pool sets a context like `w0/job-3` on each worker
//! thread (`push_context` guard), and every log line emitted from that
//! thread carries it — so interleaved multi-worker stderr remains
//! attributable without threading ids through every call site.

use std::cell::RefCell;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INSTALLED: AtomicBool = AtomicBool::new(false);

thread_local! {
    static CONTEXT: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Tag every log line from this thread with `ctx` (e.g. `w0/job-3`)
/// until the returned guard drops, which restores the previous context.
/// Contexts nest: a job-scoped context inside a worker-scoped one
/// replaces it for the job's duration only.
pub fn push_context(ctx: impl Into<String>) -> ContextGuard {
    let prev = CONTEXT.with(|c| c.replace(Some(ctx.into())));
    ContextGuard { prev }
}

/// Restores the previous thread-local log context on drop.
pub struct ContextGuard {
    prev: Option<String>,
}

impl Drop for ContextGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CONTEXT.with(|c| *c.borrow_mut() = prev);
    }
}

/// Drain buffered stderr.  Call before process exit/abort paths so the
/// final lines of a crashing or completing run are never lost.
pub fn flush() {
    log::logger().flush();
}

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let ctx = CONTEXT.with(|c| c.borrow().clone());
        match ctx {
            Some(ctx) => eprintln!(
                "[{t:9.3}s {lvl} {} {ctx}] {}",
                record.target(),
                record.args()
            ),
            None => eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args()),
        }
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

/// Install the logger once; respects `GALEN_LOG` (error|warn|info|debug|trace).
pub fn init(default_level: LevelFilter) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("GALEN_LOG").ok().as_deref() {
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("info") => LevelFilter::Info,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ => default_level,
    };
    let logger = Box::leak(Box::new(StderrLogger {
        start: Instant::now(),
    }));
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Info);
        init(LevelFilter::Debug); // second call must not panic
        log::info!("logging smoke test");
        flush();
    }

    #[test]
    fn context_nests_and_restores() {
        let read = || CONTEXT.with(|c| c.borrow().clone());
        assert_eq!(read(), None);
        {
            let _w = push_context("w0");
            assert_eq!(read().as_deref(), Some("w0"));
            {
                let _j = push_context("w0/job-1");
                assert_eq!(read().as_deref(), Some("w0/job-1"));
            }
            assert_eq!(read().as_deref(), Some("w0"), "inner pop restores outer");
        }
        assert_eq!(read(), None, "outer pop restores none");
    }
}
