//! Reader/writer for the GTEN named-tensor container (python/compile/gten.py).
//!
//! Little-endian layout:
//! `b"GTEN1\n"`, u32 count, then per tensor: u16 name-len, name, u8 dtype
//! (0=f32, 1=i32), u8 ndim, `u32 dims[ndim]`, raw row-major data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 6] = b"GTEN1\n";

/// A named tensor loaded from (or destined for) a GTEN file.
#[derive(Clone, Debug, PartialEq)]
pub enum GtenData {
    /// 32-bit float payload.
    F32(Vec<f32>),
    /// 32-bit integer payload.
    I32(Vec<i32>),
}

/// One named tensor: shape + typed payload.
#[derive(Clone, Debug, PartialEq)]
pub struct GtenTensor {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// The payload.
    pub data: GtenData,
}

impl GtenTensor {
    /// An f32 tensor (shape must match the data length).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape,
            data: GtenData::F32(data),
        }
    }

    /// Element count (product of dims).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// The f32 payload, or an error for i32 tensors.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            GtenData::F32(v) => Ok(v),
            GtenData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// The i32 payload, or an error for f32 tensors.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            GtenData::I32(v) => Ok(v),
            GtenData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }
}

/// A whole GTEN container: name -> tensor, sorted.
pub type GtenFile = BTreeMap<String, GtenTensor>;

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

/// Load every tensor in a GTEN file.
pub fn read(path: &Path) -> Result<GtenFile> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    let mut magic = [0u8; 6];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad GTEN magic", path.display());
    }
    let count = read_u32(&mut r)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let dtype = read_u8(&mut r)?;
        let ndim = read_u8(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1) * if ndim == 0 { 1 } else { 1 };
        let numel = if ndim == 0 { 1 } else { shape.iter().product() };
        let mut raw = vec![0u8; numel * 4];
        r.read_exact(&mut raw)?;
        let data = match dtype {
            0 => GtenData::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => GtenData::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            d => bail!("{name}: unknown dtype code {d}"),
        };
        let _ = n;
        out.insert(name, GtenTensor { shape, data });
    }
    Ok(out)
}

/// Write tensors (used by tests and by result exports consumed elsewhere).
pub fn write(path: &Path, tensors: &GtenFile) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u16).to_le_bytes())?;
        w.write_all(nb)?;
        let code: u8 = match &t.data {
            GtenData::F32(_) => 0,
            GtenData::I32(_) => 1,
        };
        w.write_all(&[code, t.shape.len() as u8])?;
        for &d in &t.shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            GtenData::F32(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            GtenData::I32(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("galen_gten_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let mut f: GtenFile = BTreeMap::new();
        f.insert(
            "w".into(),
            GtenTensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]),
        );
        f.insert(
            "y".into(),
            GtenTensor {
                shape: vec![4],
                data: GtenData::I32(vec![1, -2, 3, 4]),
            },
        );
        f.insert(
            "scalar".into(),
            GtenTensor {
                shape: vec![],
                data: GtenData::F32(vec![7.5]),
            },
        );
        let p = tmp("roundtrip");
        write(&p, &f).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(f, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("badmagic");
        std::fs::write(&p, b"NOPE!!rest").unwrap();
        assert!(read(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn type_mismatch_errors() {
        let t = GtenTensor::f32(vec![2], vec![1.0, 2.0]);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }
}
