//! Tiny declarative CLI argument parser (no clap offline).
//!
//! Supports `program <subcommand> --key value --flag positional...` with
//! typed accessors, defaults, and generated `--help` text.  Used by the
//! `galen` binary, the examples, and the bench harnesses.

use std::collections::BTreeMap;

/// One declared option/flag.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// Option name (without the leading `--`).
    pub name: &'static str,
    /// Help text shown in `usage`.
    pub help: &'static str,
    /// Default value (None = required).
    pub default: Option<String>,
    /// Whether this is a value-less flag.
    pub is_flag: bool,
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// A declarative command-line interface (builder style).
pub struct Cli {
    /// Program/subcommand name (usage header).
    pub name: &'static str,
    /// One-line description (usage header).
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Cli {
    /// A CLI with no options yet.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            specs: Vec::new(),
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Generated `--help` text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let kind = if spec.is_flag {
                String::new()
            } else if let Some(d) = &spec.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{}{kind}\n      {}\n", spec.name, spec.help));
        }
        s
    }

    /// Parse an argv slice (without the program name).
    pub fn parse_from(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(name) = a.strip_prefix("--") {
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    args.flags.push(name.to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?;
                    args.values.insert(name.to_string(), v.clone());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        // defaults + required check
        for spec in &self.specs {
            if spec.is_flag || args.values.contains_key(spec.name) {
                continue;
            }
            match &spec.default {
                Some(d) => {
                    args.values.insert(spec.name.to_string(), d.clone());
                }
                None => anyhow::bail!("missing required option --{}\n\n{}", spec.name, self.usage()),
            }
        }
        Ok(args)
    }

    /// Parse the process arguments.
    pub fn parse(&self) -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&argv)
    }

    /// Like `parse`, but strips a leading subcommand and ignores the
    /// `--bench` flag cargo appends to bench harness invocations.
    pub fn parse_bench(&self) -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| a != "--bench")
            .collect();
        self.parse_from(&argv)
    }
}

impl Args {
    /// String value of `name` (panics if the option was not declared).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    /// `get` parsed as usize.
    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    /// `get` parsed as f64.
    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    /// `get` parsed as u64.
    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    /// Whether a declared flag was passed.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional (non-option) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }

    /// Comma-separated list option parsed as f64s.
    pub fn get_f64_list(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        self.get_list(name)
            .iter()
            .map(|s| s.parse().map_err(|e| anyhow::anyhow!("--{name}: {e}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    fn cli() -> Cli {
        Cli::new("test", "about")
            .opt("episodes", "100", "episode count")
            .opt("target", "0.3", "compression target")
            .req("variant", "model variant")
            .flag("verbose", "log more")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse_from(&argv("--variant micro")).unwrap();
        assert_eq!(a.get_usize("episodes").unwrap(), 100);
        assert_eq!(a.get("variant"), "micro");
        assert!(!a.has_flag("verbose"));

        let a = cli()
            .parse_from(&argv("--variant resnet18s --episodes 5 --verbose pos1"))
            .unwrap();
        assert_eq!(a.get_usize("episodes").unwrap(), 5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cli().parse_from(&argv("--episodes 5")).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse_from(&argv("--variant m --nope 1")).is_err());
    }

    #[test]
    fn list_parsing() {
        let c = Cli::new("t", "a").opt("targets", "0.1,0.2,0.3", "targets");
        let a = c.parse_from(&[]).unwrap();
        assert_eq!(a.get_f64_list("targets").unwrap(), vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn usage_mentions_options() {
        let u = cli().usage();
        assert!(u.contains("--episodes"));
        assert!(u.contains("required"));
    }
}
