//! Streaming statistics used across the agents and the measurement harness:
//! Welford running mean/variance (state standardization, paper §Proposed
//! Agents), exponential moving average (reward normalization), and small
//! helpers (median, percentile) for the latency measurement wrapper.

/// Welford online mean/variance, elementwise over fixed-size vectors.
///
/// The paper standardizes agent states "using mean and variance of the
/// features ... running estimations updated using seen states, comparable to
/// a batch norm layer".
#[derive(Clone, Debug)]
pub struct RunningNorm {
    count: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl RunningNorm {
    /// A fresh estimator over `dim`-dimensional vectors.
    pub fn new(dim: usize) -> Self {
        Self {
            count: 0,
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
        }
    }

    /// The vector dimension tracked.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Number of samples seen.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold one sample into the running estimates.
    pub fn update(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.mean.len());
        self.count += 1;
        let n = self.count as f64;
        for i in 0..x.len() {
            let xi = x[i] as f64;
            let d = xi - self.mean[i];
            self.mean[i] += d / n;
            self.m2[i] += d * (xi - self.mean[i]);
        }
    }

    /// Unbiased variance of component `i` (1.0 until 2 samples seen).
    pub fn variance(&self, i: usize) -> f64 {
        if self.count < 2 {
            1.0
        } else {
            (self.m2[i] / (self.count - 1) as f64).max(1e-12)
        }
    }

    /// Standardize in place: (x - mean) / std. Identity until 2 samples seen.
    pub fn normalize(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.mean.len());
        if self.count < 2 {
            return;
        }
        for i in 0..x.len() {
            x[i] = ((x[i] as f64 - self.mean[i]) / self.variance(i).sqrt()) as f32;
        }
    }

    /// Serialize the full estimator state (checkpoints); round-trips
    /// bit-exactly through [`RunningNorm::from_json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::arr_f64(&self.mean)),
            ("m2", Json::arr_f64(&self.m2)),
        ])
    }

    /// Rebuild an estimator serialized by [`RunningNorm::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let mean = j.req_f64s("mean")?;
        let m2 = j.req_f64s("m2")?;
        anyhow::ensure!(mean.len() == m2.len(), "running-norm mean/m2 length mismatch");
        Ok(Self {
            count: j.req_f64("count")? as u64,
            mean,
            m2,
        })
    }
}

/// Exponential moving average (reward normalization: "the rewards within the
/// sampled transition batch ... are normalized using a moving average").
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// A fresh average with smoothing factor `alpha` in [0, 1].
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    /// Fold in one value and return the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    /// Current average (0 before the first update).
    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }

    /// Serialize the average state (checkpoints); `null` value = no update
    /// seen yet, so the first-sample seeding behavior survives the trip.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("alpha", Json::num(self.alpha)),
            (
                "value",
                match self.value {
                    None => Json::Null,
                    Some(v) => Json::num(v),
                },
            ),
        ])
    }

    /// Rebuild an average serialized by [`Ema::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let value = match j.req("value")? {
            crate::util::json::Json::Null => None,
            v => Some(
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("ema value is not a number"))?,
            ),
        };
        Ok(Self {
            alpha: j.req_f64("alpha")?,
            value,
        })
    }
}

/// Median of a slice (copies; used on tiny latency-sample vectors).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Arithmetic mean (0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for fewer than 2 values).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn running_norm_matches_batch_stats() {
        let mut rng = Pcg64::new(1);
        let data: Vec<[f32; 3]> = (0..1000)
            .map(|_| {
                [
                    rng.normal_scaled(5.0, 2.0) as f32,
                    rng.normal_scaled(-1.0, 0.5) as f32,
                    rng.normal_scaled(0.0, 10.0) as f32,
                ]
            })
            .collect();
        let mut norm = RunningNorm::new(3);
        for x in &data {
            norm.update(x);
        }
        assert!((norm.mean[0] - 5.0).abs() < 0.3);
        assert!((norm.variance(1).sqrt() - 0.5).abs() < 0.05);

        let mut x = data[0];
        norm.normalize(&mut x);
        assert!(x[0].abs() < 5.0); // roughly standardized
    }

    #[test]
    fn normalize_is_identity_before_two_samples() {
        let norm = RunningNorm::new(2);
        let mut x = [3.0f32, -4.0];
        norm.normalize(&mut x);
        assert_eq!(x, [3.0, -4.0]);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.2);
        for _ in 0..200 {
            e.update(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn ema_first_value_seeds() {
        let mut e = Ema::new(0.1);
        assert_eq!(e.update(4.0), 4.0);
    }

    #[test]
    fn running_norm_and_ema_json_roundtrip_exactly() {
        use crate::util::json::Json;
        let mut norm = RunningNorm::new(3);
        let mut rng = Pcg64::new(4);
        for _ in 0..17 {
            norm.update(&[
                rng.normal() as f32,
                rng.normal_scaled(3.0, 7.0) as f32,
                rng.next_f32(),
            ]);
        }
        let back = RunningNorm::from_json(&Json::parse(&norm.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.count(), norm.count());
        for i in 0..3 {
            assert_eq!(back.mean[i].to_bits(), norm.mean[i].to_bits());
            assert_eq!(back.m2[i].to_bits(), norm.m2[i].to_bits());
        }

        let mut e = Ema::new(0.05);
        let fresh = Ema::from_json(&Json::parse(&e.to_json().dump()).unwrap()).unwrap();
        assert!(fresh.value.is_none(), "pre-update state must survive");
        e.update(0.1234567890123);
        e.update(-7.5);
        let back = Ema::from_json(&Json::parse(&e.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.get().to_bits(), e.get().to_bits());
        assert_eq!(back.alpha.to_bits(), e.alpha.to_bits());
    }

    #[test]
    fn median_and_percentile() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0, 5.0], 0.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0, 5.0], 100.0), 5.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0, 5.0], 50.0), 3.0);
    }

    #[test]
    fn stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }
}
