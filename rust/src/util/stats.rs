//! Streaming statistics used across the agents and the measurement harness:
//! Welford running mean/variance (state standardization, paper §Proposed
//! Agents), exponential moving average (reward normalization), and small
//! helpers (median, percentile) for the latency measurement wrapper.

/// Welford online mean/variance, elementwise over fixed-size vectors.
///
/// The paper standardizes agent states "using mean and variance of the
/// features ... running estimations updated using seen states, comparable to
/// a batch norm layer".
#[derive(Clone, Debug)]
pub struct RunningNorm {
    count: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl RunningNorm {
    /// A fresh estimator over `dim`-dimensional vectors.
    pub fn new(dim: usize) -> Self {
        Self {
            count: 0,
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
        }
    }

    /// The vector dimension tracked.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Number of samples seen.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold one sample into the running estimates.
    pub fn update(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.mean.len());
        self.count += 1;
        let n = self.count as f64;
        for i in 0..x.len() {
            let xi = x[i] as f64;
            let d = xi - self.mean[i];
            self.mean[i] += d / n;
            self.m2[i] += d * (xi - self.mean[i]);
        }
    }

    /// Unbiased variance of component `i` (1.0 until 2 samples seen).
    pub fn variance(&self, i: usize) -> f64 {
        if self.count < 2 {
            1.0
        } else {
            (self.m2[i] / (self.count - 1) as f64).max(1e-12)
        }
    }

    /// Standardize in place: (x - mean) / std. Identity until 2 samples seen.
    pub fn normalize(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.mean.len());
        if self.count < 2 {
            return;
        }
        for i in 0..x.len() {
            x[i] = ((x[i] as f64 - self.mean[i]) / self.variance(i).sqrt()) as f32;
        }
    }
}

/// Exponential moving average (reward normalization: "the rewards within the
/// sampled transition batch ... are normalized using a moving average").
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// A fresh average with smoothing factor `alpha` in [0, 1].
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    /// Fold in one value and return the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    /// Current average (0 before the first update).
    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

/// Median of a slice (copies; used on tiny latency-sample vectors).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Arithmetic mean (0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for fewer than 2 values).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn running_norm_matches_batch_stats() {
        let mut rng = Pcg64::new(1);
        let data: Vec<[f32; 3]> = (0..1000)
            .map(|_| {
                [
                    rng.normal_scaled(5.0, 2.0) as f32,
                    rng.normal_scaled(-1.0, 0.5) as f32,
                    rng.normal_scaled(0.0, 10.0) as f32,
                ]
            })
            .collect();
        let mut norm = RunningNorm::new(3);
        for x in &data {
            norm.update(x);
        }
        assert!((norm.mean[0] - 5.0).abs() < 0.3);
        assert!((norm.variance(1).sqrt() - 0.5).abs() < 0.05);

        let mut x = data[0];
        norm.normalize(&mut x);
        assert!(x[0].abs() < 5.0); // roughly standardized
    }

    #[test]
    fn normalize_is_identity_before_two_samples() {
        let norm = RunningNorm::new(2);
        let mut x = [3.0f32, -4.0];
        norm.normalize(&mut x);
        assert_eq!(x, [3.0, -4.0]);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.2);
        for _ in 0..200 {
            e.update(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn ema_first_value_seeds() {
        let mut e = Ema::new(0.1);
        assert_eq!(e.update(4.0), 4.0);
    }

    #[test]
    fn median_and_percentile() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0, 5.0], 0.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0, 5.0], 100.0), 5.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0, 5.0], 50.0), 3.0);
    }

    #[test]
    fn stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }
}
