//! Minimal JSON parser + serializer (no serde offline).
//!
//! Used for the model-structure manifests emitted by `python/compile/aot.py`
//! (`artifacts/meta_*.json`), the run configuration files in `configs/`, and
//! the experiment result files written to `results/`.  Supports the full
//! JSON grammar except for exotic number forms; numbers are stored as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true`/`false`.
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with its byte position.
#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl Json {
    // ---------------- accessors ----------------
    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The number value truncated to i64.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    /// The bool value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup (None for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj.get(key)` with typed unwrap helpers that error loudly.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }
    /// Required numeric field.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a number"))
    }
    /// Required numeric field truncated to usize.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }
    /// Required string field.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a string"))
    }
    /// Required boolean field.
    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a bool"))
    }
    /// Required array field.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not an array"))
    }
    /// Required numeric array field decoded as f64s.
    pub fn req_f64s(&self, key: &str) -> anyhow::Result<Vec<f64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("json key '{key}' holds a non-number"))
            })
            .collect()
    }
    /// Required numeric array field decoded as f32s.
    ///
    /// Every f32 embeds exactly into f64 and the serializer prints the
    /// shortest round-tripping decimal, so values written by `arr_f32`
    /// decode bit-identically — the checkpoint code relies on this.
    pub fn req_f32s(&self, key: &str) -> anyhow::Result<Vec<f32>> {
        Ok(self.req_f64s(key)?.into_iter().map(|v| v as f32).collect())
    }
    /// This value decoded as a numeric array of f32s (for arrays nested
    /// inside arrays, where no key is available; same bit-exactness
    /// guarantee as [`Json::req_f32s`]).
    pub fn f32s(&self) -> anyhow::Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected a numeric array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| anyhow::anyhow!("numeric array holds a non-number"))
            })
            .collect()
    }
    /// Required hex-encoded u64 field (see [`Json::hex64`]).
    pub fn req_hex64(&self, key: &str) -> anyhow::Result<u64> {
        let s = self.req_str(key)?;
        u64::from_str_radix(s, 16)
            .map_err(|_| anyhow::anyhow!("json key '{key}' is not a hex u64 ('{s}')"))
    }

    // ---------------- constructors ----------------
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Build a number.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    /// Build a number array from f64s.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    /// Build a number array from f32s.
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// Build a number array from usizes.
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// Encode a u64 as a fixed-width hex string (u64s above 2^53 do not
    /// survive the f64 number path, so seeds and hashes travel as hex).
    pub fn hex64(v: u64) -> Json {
        Json::Str(format!("{v:016x}"))
    }

    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Read and parse a JSON file.
    pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&s).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    /// Pretty-print to a file, creating parent directories.
    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.pretty(0))?;
        Ok(())
    }

    /// Durable variant of [`Json::write_file`]: pretty-print to a sibling
    /// temp file, fsync it, atomically rename it over `path`, and fsync the
    /// parent directory so the rename itself survives power loss.  A crash
    /// mid-write can never leave a torn or half-written document behind —
    /// readers see either the old file or the complete new one.  The temp
    /// name is unique per process and call, so concurrent writers (e.g.
    /// serve workers persisting profilers that share one manifest path)
    /// each rename their *own* complete file instead of interleaving into a
    /// shared one.  Used for crash-recovery artifacts (search checkpoints,
    /// profile manifests); pair load sites with [`cleanup_stale_temps`] to
    /// reap temps orphaned by a crash between create and rename.
    pub fn write_file_atomic(&self, path: &std::path::Path) -> anyhow::Result<()> {
        write_bytes_atomic(path, self.pretty(0).as_bytes())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Human-readable serialization (2-space indent).
    pub fn pretty(&self, indent: usize) -> String {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                let flat = a.iter().all(|v| matches!(v, Json::Num(_) | Json::Str(_) | Json::Bool(_) | Json::Null));
                if flat && a.len() <= 16 {
                    self.dump()
                } else {
                    let items: Vec<String> =
                        a.iter().map(|v| format!("{pad1}{}", v.pretty(indent + 1))).collect();
                    format!("[\n{}\n{pad}]", items.join(",\n"))
                }
            }
            Json::Obj(o) if !o.is_empty() => {
                let items: Vec<String> = o
                    .iter()
                    .map(|(k, v)| {
                        let mut ks = String::new();
                        write_str(k, &mut ks);
                        format!("{pad1}{ks}: {}", v.pretty(indent + 1))
                    })
                    .collect();
                format!("{{\n{}\n{pad}}}", items.join(",\n"))
            }
            _ => self.dump(),
        }
    }
}

/// Fsync a directory so a rename or file creation inside it is durable
/// (POSIX requires syncing the directory for the *entry* to survive power
/// loss; the file's own fsync only covers its contents).  A no-op on
/// platforms where directories cannot be opened for syncing.
pub fn fsync_dir(dir: &std::path::Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::fs::File::open(dir)?.sync_all()?;
    }
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// Atomically and durably write raw bytes to `path`: write to a sibling
/// temp file, fsync it, rename it over `path`, and fsync the parent
/// directory so the rename itself survives power loss.  A crash mid-write
/// can never leave a torn or half-written file behind — readers see either
/// the old file or the complete new one.  The temp name is unique per
/// process and call, so concurrent writers (e.g. serve workers packaging
/// artifacts into a shared output directory) each rename their *own*
/// complete file instead of interleaving into a shared one.  This is the
/// byte-level core of [`Json::write_file_atomic`]; binary writers (the
/// artifact packer) use it directly.  Pair load sites with
/// [`cleanup_stale_temps`] to reap temps orphaned by a crash between
/// create and rename.
pub fn write_bytes_atomic(path: &std::path::Path, bytes: &[u8]) -> anyhow::Result<()> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("no file name in {}", path.display()))?
        .to_string_lossy()
        .into_owned();
    let tmp = path.with_file_name(format!(
        ".{file_name}.{}-{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let write = (|| -> anyhow::Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", tmp.display()))?;
        f.write_all(bytes)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        f.sync_data()
            .map_err(|e| anyhow::anyhow!("syncing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            anyhow::anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display())
        })?;
        Ok(())
    })();
    if write.is_err() {
        // don't leave our own temp behind on a failed write/rename
        let _ = std::fs::remove_file(&tmp);
        return write;
    }
    if let Some(dir) = path.parent() {
        fsync_dir(dir).map_err(|e| anyhow::anyhow!("syncing dir {}: {e}", dir.display()))?;
    }
    Ok(())
}

/// Best-effort reaper for temp files orphaned by a crash between
/// [`Json::write_file_atomic`]'s create and rename: removes siblings of
/// `path` matching its `.<name>.<pid>-<seq>.tmp` pattern whose pid is not
/// this process (a live writer in this process may still rename its temp).
/// Call at load sites (manifest/checkpoint readers), never on hot paths.
pub fn cleanup_stale_temps(path: &std::path::Path) {
    let (Some(dir), Some(name)) = (path.parent(), path.file_name()) else {
        return;
    };
    let prefix = format!(".{}.", name.to_string_lossy());
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let own_pid = std::process::id().to_string();
    for entry in entries.flatten() {
        let fname = entry.file_name();
        let fname = fname.to_string_lossy();
        let Some(middle) = fname
            .strip_prefix(prefix.as_str())
            .and_then(|r| r.strip_suffix(".tmp"))
        else {
            continue;
        };
        match middle.split_once('-') {
            Some((pid, seq)) if pid != own_pid && !pid.is_empty() && !seq.is_empty() => {
                log::info!("removing orphaned temp file {}", entry.path().display());
                let _ = std::fs::remove_file(entry.path());
            }
            _ => {}
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    // negative zero must skip the integer fast path (`0` would decode as
    // +0.0) — `{n}` prints "-0", which parses back sign-exact; the
    // checkpoint format's bit-exactness guarantee depends on it
    if !n.is_finite() {
        out.push_str("null"); // JSON has no inf/nan
    } else if n == n.trunc() && n.abs() < 1e15 && !(n == 0.0 && n.is_sign_negative()) {
        fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
    } else {
        fmt::Write::write_fmt(out, format_args!("{n}")).unwrap();
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let s = unsafe { std::str::from_utf8_unchecked(&self.b[start..]) };
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.req_str("c").unwrap(), "x");
        let a = v.req_arr("a").unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"galen","nums":[1,2.5,-3],"nested":{"ok":true,"n":null},"s":"a\"b\\c"}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, back);
        let pretty = Json::parse(&v.pretty(0)).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn errors_have_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] junk").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn negative_zero_survives_the_roundtrip() {
        assert_eq!(Json::Num(-0.0).dump(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // positive zero keeps the integer fast path
        assert_eq!(Json::Num(0.0).dump(), "0");
    }

    #[test]
    fn typed_array_and_hex_helpers_roundtrip() {
        let xs32 = [1.5f32, -0.25, 3.0e-7, f32::MIN_POSITIVE];
        let xs64 = [0.1f64, -2.0, 1e-300];
        let j = Json::obj(vec![
            ("f32s", Json::arr_f32(&xs32)),
            ("f64s", Json::arr_f64(&xs64)),
            ("seed", Json::hex64(0xdead_beef_cafe_f00d)),
        ]);
        let back = Json::parse(&j.dump()).unwrap();
        // bit-exact decode: the checkpoint format depends on this
        let f32s = back.req_f32s("f32s").unwrap();
        for (a, b) in f32s.iter().zip(&xs32) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let f64s = back.req_f64s("f64s").unwrap();
        for (a, b) in f64s.iter().zip(&xs64) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.req_hex64("seed").unwrap(), 0xdead_beef_cafe_f00d);
        assert!(back.req_hex64("f32s").is_err());
    }

    fn temp_siblings(dir: &std::path::Path) -> Vec<String> {
        std::fs::read_dir(dir)
            .map(|rd| {
                rd.flatten()
                    .map(|e| e.file_name().to_string_lossy().into_owned())
                    .filter(|n| n.ends_with(".tmp"))
                    .collect()
            })
            .unwrap_or_default()
    }

    #[test]
    fn write_file_atomic_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("galen_json_atomic_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("doc.json");
        let a = Json::obj(vec![("v", Json::num(1.0))]);
        a.write_file_atomic(&path).unwrap();
        assert_eq!(Json::read_file(&path).unwrap(), a);
        let b = Json::obj(vec![("v", Json::num(2.0))]);
        b.write_file_atomic(&path).unwrap();
        assert_eq!(Json::read_file(&path).unwrap(), b);
        assert_eq!(temp_siblings(&dir), Vec::<String>::new(), "temp files must not survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_file_atomic_concurrent_writers_never_tear() {
        let dir = std::env::temp_dir().join(format!("galen_json_race_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("shared.json");
        // many threads hammer the same destination path: every writer owns
        // a distinct temp, so the published file is always one writer's
        // complete document, never an interleaving
        std::thread::scope(|scope| {
            for t in 0..8u32 {
                let path = path.clone();
                scope.spawn(move || {
                    for i in 0..16 {
                        let doc = Json::obj(vec![
                            ("writer", Json::num(t as f64)),
                            ("iter", Json::num(i as f64)),
                            ("pad", Json::str("x".repeat(512))),
                        ]);
                        doc.write_file_atomic(&path).unwrap();
                        let seen = Json::read_file(&path).unwrap();
                        assert_eq!(seen.req_str("pad").unwrap().len(), 512);
                    }
                });
            }
        });
        assert_eq!(temp_siblings(&dir), Vec::<String>::new());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cleanup_reaps_foreign_orphans_only() {
        let dir = std::env::temp_dir().join(format!("galen_json_reap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("doc.json");
        // a dead process's orphan, our own in-flight temp, and a bystander
        let foreign = dir.join(format!(".doc.json.{}-0.tmp", std::process::id().wrapping_add(1)));
        let ours = dir.join(format!(".doc.json.{}-7.tmp", std::process::id()));
        let bystander = dir.join("other.tmp");
        for f in [&foreign, &ours, &bystander] {
            std::fs::write(f, "x").unwrap();
        }
        cleanup_stale_temps(&path);
        assert!(!foreign.exists(), "foreign orphan must be reaped");
        assert!(ours.exists(), "this process's temp may still be renamed");
        assert!(bystander.exists(), "unrelated files are untouched");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn real_meta_shape() {
        // mirrors the structure of artifacts/meta_*.json
        let src = r#"{"variant":"micro","img":32,"layers":[{"name":"stem","kind":"conv","cin":3,"cout":8,"prunable":false,"group":0}],"params":[{"name":"stem.w","shape":[3,3,3,8],"trainable":true}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req_usize("img").unwrap(), 32);
        let layers = v.req_arr("layers").unwrap();
        assert_eq!(layers[0].req_str("kind").unwrap(), "conv");
        assert_eq!(layers[0].req_usize("cout").unwrap(), 8);
        assert!(!layers[0].req_bool("prunable").unwrap());
    }
}
