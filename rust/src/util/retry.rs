//! Bounded exponential backoff with deterministic jitter.
//!
//! Transient faults (a glitched kernel measurement, a busy filesystem)
//! deserve a few retries before anyone degrades or fails — but retry
//! timing must not become a hidden source of nondeterminism in an
//! otherwise bit-reproducible system.  The jitter here is a pure function
//! of `(seed, attempt)` via the shared [`Fnv1a`] hasher (the same recipe
//! as `search::job_seed`), so two runs with the same seed back off on the
//! identical schedule.

use std::time::Duration;

use anyhow::Result;

use super::Fnv1a;

/// Domain-separation salt so backoff streams never collide with other
/// `Fnv1a`-derived streams (job seeds, cache keys) built from the same seed.
const JITTER_SALT: u64 = 0xb0ff_5eed_7e57_a11e;

/// A bounded exponential backoff schedule: `delay(a) = jitter * min(cap,
/// base * 2^a)` with deterministic jitter in `[0.5, 1.0)`.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    /// Total attempts (>= 1); `run` sleeps between attempts, never after
    /// the last.
    pub attempts: u32,
    /// Delay before the first retry (attempt 0's failure).
    pub base: Duration,
    /// Upper bound on the un-jittered delay.
    pub cap: Duration,
    /// Seed of the jitter stream (pure function — no wall clock involved).
    pub seed: u64,
}

impl Backoff {
    /// A schedule of `attempts` tries backing off from `base` up to `cap`.
    pub fn new(attempts: u32, base: Duration, cap: Duration, seed: u64) -> Self {
        Self {
            attempts: attempts.max(1),
            base,
            cap,
            seed,
        }
    }

    /// The delay slept after failed attempt `attempt` (0-based): pure in
    /// `(self, attempt)`, monotone in expectation, capped at `cap`.
    pub fn delay(&self, attempt: u32) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32.checked_shl(attempt.min(20)).unwrap_or(u32::MAX))
            .min(self.cap);
        let mut h = Fnv1a::seeded(self.seed ^ JITTER_SALT);
        h.mix(attempt as u64);
        // top 53 bits -> uniform f64 in [0, 1), mapped onto [0.5, 1.0)
        let frac = (h.finish() >> 11) as f64 / (1u64 << 53) as f64;
        exp.mul_f64(0.5 + 0.5 * frac)
    }

    /// Run `op` up to `attempts` times, sleeping `delay(attempt)` between
    /// failures; returns the first success or the last error annotated with
    /// the attempt count.
    pub fn run<T>(&self, mut op: impl FnMut(u32) -> Result<T>) -> Result<T> {
        let mut last = None;
        for attempt in 0..self.attempts {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if attempt + 1 < self.attempts {
                        log::debug!(
                            "retry: attempt {} failed ({e:#}); backing off {:?}",
                            attempt + 1,
                            self.delay(attempt)
                        );
                        std::thread::sleep(self.delay(attempt));
                    }
                    last = Some(e);
                }
            }
        }
        // attempts >= 1, so at least one op ran and last is populated
        Err(last
            .unwrap_or_else(|| anyhow::anyhow!("no attempts were made"))
            .context(format!("after {} attempt(s)", self.attempts)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast(attempts: u32, seed: u64) -> Backoff {
        Backoff::new(attempts, Duration::from_micros(1), Duration::from_micros(8), seed)
    }

    #[test]
    fn delay_is_deterministic_bounded_and_jittered() {
        let b = Backoff::new(5, Duration::from_millis(10), Duration::from_millis(80), 42);
        for a in 0..32 {
            let d = b.delay(a);
            assert_eq!(d, b.delay(a), "pure function of (seed, attempt)");
            let exp = Duration::from_millis(10)
                .saturating_mul(1u32 << a.min(20))
                .min(Duration::from_millis(80));
            assert!(d >= exp.mul_f64(0.5) && d < exp, "attempt {a}: {d:?} vs cap {exp:?}");
        }
        // different seeds jitter differently (some attempt must differ)
        let c = Backoff::new(5, Duration::from_millis(10), Duration::from_millis(80), 43);
        assert!((0..8).any(|a| b.delay(a) != c.delay(a)));
    }

    #[test]
    fn huge_attempt_index_saturates_at_cap() {
        let b = Backoff::new(3, Duration::from_millis(1), Duration::from_secs(1), 7);
        assert!(b.delay(u32::MAX) <= Duration::from_secs(1));
    }

    #[test]
    fn run_returns_first_success() {
        let mut calls = 0;
        let r: Result<i32> = fast(5, 1).run(|attempt| {
            calls += 1;
            if attempt < 2 {
                anyhow::bail!("transient");
            }
            Ok(99)
        });
        assert_eq!(r.unwrap(), 99);
        assert_eq!(calls, 3);
    }

    #[test]
    fn run_exhausts_attempts_and_reports_count() {
        let mut calls = 0;
        let r: Result<()> = fast(4, 2).run(|_| {
            calls += 1;
            anyhow::bail!("always down")
        });
        assert_eq!(calls, 4);
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("4 attempt(s)"), "{msg}");
        assert!(msg.contains("always down"), "{msg}");
    }

    #[test]
    fn single_attempt_never_sleeps_or_retries() {
        let mut calls = 0;
        let r: Result<()> = Backoff::new(0, Duration::ZERO, Duration::ZERO, 0).run(|_| {
            calls += 1;
            anyhow::bail!("down")
        });
        assert!(r.is_err());
        assert_eq!(calls, 1, "attempts clamps to >= 1");
    }
}
