//! Poison-recovering synchronization helpers.
//!
//! A panicking worker poisons every `Mutex`/`RwLock` it holds; with the
//! standard library's default behavior, every later `.lock().unwrap()` on
//! that lock panics too, cascading one job's failure into the whole
//! process.  The serve worker pool isolates panics per job
//! (`coordinator::service`), so the rest of the service must keep operating
//! on state a panicked worker touched — these helpers recover the guard
//! from a poisoned lock instead of propagating the poison.
//!
//! Recovery is sound here because every structure guarded by these locks is
//! kept consistent under single `lock` calls (no multi-step invariants that
//! a mid-update panic could tear): job state transitions happen in one
//! critical section, and the shared latency caches are insert-only maps of
//! values that are pure functions of their keys.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read-lock `l`, recovering the guard if a previous writer panicked.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock `l`, recovering the guard if a previous writer panicked.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Park on `cv`, recovering the re-acquired guard if another holder of the
/// mutex panicked while we slept.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Panic while holding `m` so it is poisoned (from a scoped thread, so
    /// the panic does not fail the test itself).
    fn poison(m: &Arc<Mutex<i32>>) {
        let m = m.clone();
        let h = std::thread::spawn(move || {
            let _guard = m.lock().unwrap();
            panic!("poison the lock");
        });
        assert!(h.join().is_err());
    }

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7));
        poison(&m);
        assert!(m.lock().is_err(), "the mutex must actually be poisoned");
        assert_eq!(*lock(&m), 7, "recovery sees the pre-panic value");
        *lock(&m) = 8;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_recovers_from_poison() {
        let l = Arc::new(RwLock::new(1));
        {
            let l = l.clone();
            let h = std::thread::spawn(move || {
                let _guard = l.write().unwrap();
                panic!("poison the rwlock");
            });
            assert!(h.join().is_err());
        }
        assert_eq!(*read(&l), 1);
        *write(&l) = 2;
        assert_eq!(*read(&l), 2);
    }

    #[test]
    fn wait_returns_signalled_guard() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let waiter = {
            let pair = pair.clone();
            std::thread::spawn(move || {
                let (m, cv) = &*pair;
                let mut ready = lock(m);
                while !*ready {
                    ready = wait(cv, ready);
                }
            })
        };
        let (m, cv) = &*pair;
        *lock(m) = true;
        cv.notify_all();
        waiter.join().unwrap();
    }
}
