//! From-scratch substrates (no third-party equivalents available offline):
//! RNG + samplers, JSON, GTEN tensor files, streaming stats, CLI, logging,
//! and a small scoped-thread helper used for parallel experiment sweeps.

/// Declarative CLI argument parsing (no clap offline).
pub mod cli;
/// GTEN tensor-file reader/writer.
pub mod gten;
/// Minimal JSON parser + serializer (no serde offline).
pub mod json;
/// Env-configurable logger (`GALEN_LOG`).
pub mod logging;
/// Bounded exponential backoff with deterministic jitter.
pub mod retry;
/// PCG64 PRNG + samplers.
pub mod rng;
/// Streaming statistics (Welford, EMA, median/percentile).
pub mod stats;
/// Poison-recovering lock helpers.
pub mod sync;

/// Incremental FNV-1a 64-bit hasher: the shared primitive behind the
/// hardware layer's cache keys and fingerprints (`hw::sim` measurement
/// streams, `hw::profiler` config keys / target fingerprints).  One
/// implementation, so the keyed structures can never drift apart.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Fnv1a {
    /// Hasher at the standard FNV-1a offset basis.
    pub fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    /// Seed with an existing hash value (stream-chaining).
    pub fn seeded(h: u64) -> Self {
        Self(h)
    }

    /// Fold one 64-bit value into the hash.
    pub fn mix(&mut self, x: u64) -> &mut Self {
        self.0 ^= x;
        self.0 = self.0.wrapping_mul(0x0100_0000_01b3);
        self
    }

    /// Fold a byte string into the hash (byte by byte).
    pub fn mix_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.mix(b as u64);
        }
        self
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// Worker count for the parallel compute kernels: the `GALEN_NUM_THREADS`
/// environment variable when set (>= 1), otherwise the machine's available
/// parallelism. Read once and cached for the process lifetime.
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("GALEN_NUM_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Split the row-major buffer `data` (`rows` rows) into up to `workers`
/// contiguous row blocks and run `f(first_row, block)` on each, one scoped
/// thread per block.
///
/// Every invocation owns a disjoint block, and the block boundaries are a
/// pure function of `rows` and `workers` — so the decomposition is
/// deterministic, and a kernel whose per-row computation does not depend on
/// the block split produces bit-identical results for every worker count.
/// Panics in workers propagate.
pub fn parallel_row_blocks<F>(data: &mut [f32], rows: usize, workers: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let row_len = if rows == 0 { 0 } else { data.len() / rows };
    debug_assert!(rows == 0 || data.len() == rows * row_len);
    let workers = workers.clamp(1, rows.max(1));
    if workers == 1 || row_len == 0 {
        f(0, data);
        return;
    }
    let block_rows = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        for (b, block) in data.chunks_mut(block_rows * row_len).enumerate() {
            let f = &f;
            scope.spawn(move || f(b * block_rows, block));
        }
    });
}

/// Run `f` over `items` with up to `workers` scoped threads, preserving
/// input order in the output. Panics in workers propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: std::sync::Mutex<std::collections::VecDeque<(usize, T)>> =
        std::sync::Mutex::new(items.into_iter().enumerate().collect());
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_mx = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let job = sync::lock(&queue).pop_front();
                match job {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item);
                        sync::lock(&slots_mx)[i] = Some(r);
                    }
                }
            });
        }
    });
    slots.into_iter().map(|r| r.expect("worker dropped job")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..57).collect();
        let ys = parallel_map(xs.clone(), 4, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_worker() {
        let ys = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let ys: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn row_blocks_cover_all_rows_once() {
        for rows in [0usize, 1, 2, 7, 16, 33] {
            for workers in [1usize, 2, 3, 8, 64] {
                let row_len = 3;
                let mut data = vec![0.0f32; rows * row_len];
                parallel_row_blocks(&mut data, rows, workers, |r0, block| {
                    let n = block.len() / row_len.max(1);
                    for i in 0..n {
                        for x in &mut block[i * row_len..(i + 1) * row_len] {
                            *x += (r0 + i) as f32;
                        }
                    }
                });
                for (i, chunk) in data.chunks(row_len).enumerate() {
                    assert!(
                        chunk.iter().all(|&x| x == i as f32),
                        "rows={rows} workers={workers} row {i}: {chunk:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn row_blocks_zero_width_rows() {
        let mut data: Vec<f32> = Vec::new();
        parallel_row_blocks(&mut data, 5, 4, |r0, block| {
            assert_eq!(r0, 0);
            assert!(block.is_empty());
        });
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn fnv1a_separates_sequences_and_orders() {
        let h = |xs: &[u64]| {
            let mut f = Fnv1a::new();
            for &x in xs {
                f.mix(x);
            }
            f.finish()
        };
        assert_eq!(h(&[1, 2, 3]), h(&[1, 2, 3]));
        assert_ne!(h(&[1, 2, 3]), h(&[3, 2, 1]), "order-sensitive");
        assert_ne!(h(&[1]), h(&[1, 0]), "length-sensitive");
        let mut a = Fnv1a::new();
        a.mix_bytes(b"abc");
        let mut b = Fnv1a::new();
        for &c in b"abc" {
            b.mix(c as u64);
        }
        assert_eq!(a.finish(), b.finish());
        assert_eq!(Fnv1a::seeded(Fnv1a::new().finish()).finish(), Fnv1a::new().finish());
    }
}
