//! From-scratch substrates (no third-party equivalents available offline):
//! RNG + samplers, JSON, GTEN tensor files, streaming stats, CLI, logging,
//! and a small scoped-thread helper used for parallel experiment sweeps.

pub mod cli;
pub mod gten;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

/// Run `f` over `items` with up to `workers` scoped threads, preserving
/// input order in the output. Panics in workers propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: std::sync::Mutex<std::collections::VecDeque<(usize, T)>> =
        std::sync::Mutex::new(items.into_iter().enumerate().collect());
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_mx = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop_front();
                match job {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item);
                        slots_mx.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    slots.into_iter().map(|r| r.expect("worker dropped job")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..57).collect();
        let ys = parallel_map(xs.clone(), 4, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_worker() {
        let ys = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let ys: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }
}
