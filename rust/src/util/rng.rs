//! Seedable PRNG + the samplers the DDPG agents need.
//!
//! No `rand` crate is available offline, so this implements PCG64 (O'Neill,
//! PCG-XSL-RR 128/64) from scratch plus Box-Muller Gaussians and the
//! truncated normal of paper Eq. 7 (exploration noise is sampled from
//! `N_trunc(mu, sigma^2, 0, 1)` by rejection, which is cheap for the
//! sigma <= 0.5 range the paper uses).

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seeded generator on an explicit stream (independent sequences
    /// for equal seeds and distinct streams).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// The raw generator state `(state, inc)` — everything needed to
    /// continue the stream bit-identically (see [`Pcg64::from_snapshot`]).
    pub fn snapshot(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::snapshot`]; the restored stream
    /// produces exactly the draws the snapshotted one would have.
    pub fn from_snapshot(state: u128, inc: u128) -> Self {
        Self { state, inc }
    }

    /// Serialize the generator state (hex strings: u128s do not survive the
    /// JSON f64 number path).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("state", Json::str(format!("{:032x}", self.state))),
            ("inc", Json::str(format!("{:032x}", self.inc))),
        ])
    }

    /// Rebuild a generator serialized by [`Pcg64::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let hex = |key: &str| -> anyhow::Result<u128> {
            let s = j.req_str(key)?;
            u128::from_str_radix(s, 16)
                .map_err(|_| anyhow::anyhow!("rng '{key}' is not a hex u128 ('{s}')"))
        };
        Ok(Self::from_snapshot(hex("state")?, hex("inc")?))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection to avoid modulo bias.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Truncated normal on [lo, hi] (paper Eq. 7) by rejection sampling,
    /// falling back to clamped uniform if acceptance collapses (very large
    /// sigma or mu far outside the interval).
    pub fn truncated_normal(&mut self, mu: f64, sigma: f64, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo < hi);
        if sigma <= 0.0 {
            return mu.clamp(lo, hi);
        }
        for _ in 0..64 {
            let x = self.normal_scaled(mu, sigma);
            if (lo..=hi).contains(&x) {
                return x;
            }
        }
        self.uniform(lo, hi)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.sample_indices_into(n, k, &mut idx);
        idx
    }

    /// `sample_indices` writing into a reusable buffer — identical draws, no
    /// per-call allocation once the buffer has grown to `n` (hot path: the
    /// DDPG replay sampler).
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        debug_assert!(k <= n);
        // partial Fisher-Yates on an index vector
        out.clear();
        out.extend(0..n);
        for i in 0..k {
            let j = i + self.below(n - i);
            out.swap(i, j);
        }
        out.truncate(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn truncated_normal_bounds() {
        let mut rng = Pcg64::new(13);
        for _ in 0..5_000 {
            let x = rng.truncated_normal(0.8, 0.5, 0.0, 1.0);
            assert!((0.0..=1.0).contains(&x));
        }
        // concentrates near mu when sigma is small
        let mut sum = 0.0;
        for _ in 0..5_000 {
            sum += rng.truncated_normal(0.3, 0.01, 0.0, 1.0);
        }
        assert!((sum / 5_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn truncated_normal_degenerate_sigma() {
        let mut rng = Pcg64::new(17);
        assert_eq!(rng.truncated_normal(2.0, 0.0, 0.0, 1.0), 1.0);
        assert_eq!(rng.truncated_normal(-3.0, 0.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(23);
        for _ in 0..100 {
            let s = rng.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8);
        }
    }

    #[test]
    fn sample_indices_into_matches_allocating_path() {
        let mut a = Pcg64::new(29);
        let mut b = Pcg64::new(29);
        let mut buf = Vec::new();
        for _ in 0..50 {
            let s = a.sample_indices(40, 16);
            b.sample_indices_into(40, 16, &mut buf);
            assert_eq!(s, buf, "draw-for-draw parity");
        }
        // buffer capacity is stable after the first call at a given n
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        for _ in 0..20 {
            b.sample_indices_into(40, 16, &mut buf);
        }
        assert_eq!((buf.capacity(), buf.as_ptr()), (cap, ptr));
    }

    #[test]
    fn snapshot_resumes_the_stream_exactly() {
        let mut rng = Pcg64::new(99);
        for _ in 0..37 {
            rng.next_u64();
        }
        let (state, inc) = rng.snapshot();
        let mut direct = Pcg64::from_snapshot(state, inc);
        let mut via_json = Pcg64::from_json(&rng.to_json()).unwrap();
        for _ in 0..100 {
            let expect = rng.next_u64();
            assert_eq!(direct.next_u64(), expect);
            assert_eq!(via_json.next_u64(), expect);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
