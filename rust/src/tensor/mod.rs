//! Minimal dense f32 matrix used by the agent networks (rust/src/nn).
//!
//! Row-major `Mat` with exactly the operations DDPG needs: GEMM (with
//! optional transposes), broadcast row ops, elementwise maps.  The GEMMs are
//! the L3 hot path (profiled in rust/benches/hot_paths.rs) and are written
//! as cache-blocked, multi-accumulator kernels with `_into` variants that
//! reuse caller buffers, plus a deterministic row-parallel path
//! (`util::parallel_row_blocks`) for large shapes.
//!
//! Determinism contract: every kernel accumulates the contributions of each
//! output element in a fixed order that does not depend on the worker count
//! (each thread owns disjoint output rows and runs the identical per-row
//! code), so N-thread results are bit-identical to 1-thread results.  The
//! `GALEN_NUM_THREADS` environment variable caps the worker count
//! (`util::num_threads`).

/// Depthwise convolution kernels (f32 and i8, MobileNet-style workloads).
pub mod depthwise;
/// Quantized tensor types and the i8 GEMM kernels.
pub mod quant;
/// Runtime-dispatched SIMD kernels (AVX2/NEON) with the scalar kernels in
/// this file as the bit-exactness oracle, plus the tile autotuner.
pub mod simd;

use crate::util::{num_threads, parallel_row_blocks};

/// K-panel height of the blocked *scalar* GEMM: a `KC x n` slab of the
/// right-hand matrix is streamed repeatedly while it is still
/// cache-resident.  Shared with the scalar quantized kernels in `quant`.
/// The SIMD kernels read their (autotuned) panel height from
/// `simd::TileConfig` instead; the scalar oracle keeps this fixed constant
/// so its output — the reference every SIMD path must match bit-for-bit —
/// never shifts under tuning.
const KC: usize = 256;

/// Worker count for a GEMM of `macs` multiply-accumulates, scaled so every
/// thread gets at least ~`par_min_macs` of work (thread spawn is ~tens of
/// microseconds; a just-over-threshold GEMM must not fan out to a
/// many-core machine's full width, where per-call spawn overhead would
/// dominate the kernel).
///
/// The threshold comes from the autotuned `simd::TileConfig` (measured per
/// target at first profiler use; `1 << 21` as the untuned default — the
/// historical compile-time constant).  The old
/// `(macs / PAR_MIN_MACS).clamp(1, ..)` formula left every GEMM with
/// `t <= macs < 2t` on a single worker; now the crossover goes straight to
/// two workers.  Worker count never affects results (each worker owns
/// disjoint output rows), so the threshold is a pure perf knob.
fn gemm_workers(macs: usize) -> usize {
    let t = simd::tile_config().par_min_macs.max(1);
    if macs < t {
        1
    } else {
        (macs / t).max(2).min(num_threads())
    }
}

/// Rows `r0..` of `A @ B` into `out_block` (`A` is `m x k_dim`, `B` is
/// `k_dim x n`, all row-major).  i-k-j loop, k blocked in `KC` panels and
/// unrolled 4-wide (four independent accumulation streams per output row).
/// Per output element the k contributions are consumed in ascending order in
/// fixed groups of four — identical for every block split.
fn gemm_rows(a: &[f32], k_dim: usize, b: &[f32], n: usize, r0: usize, out_block: &mut [f32]) {
    out_block.fill(0.0);
    if n == 0 || k_dim == 0 {
        return;
    }
    let rows = out_block.len() / n;
    for k0 in (0..k_dim).step_by(KC) {
        let k1 = (k0 + KC).min(k_dim);
        for i in 0..rows {
            let arow = &a[(r0 + i) * k_dim..(r0 + i) * k_dim + k_dim];
            let orow = &mut out_block[i * n..(i + 1) * n];
            let mut k = k0;
            while k + 4 <= k1 {
                let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                let b0 = &b[k * n..(k + 1) * n];
                let b1 = &b[(k + 1) * n..(k + 2) * n];
                let b2 = &b[(k + 2) * n..(k + 3) * n];
                let b3 = &b[(k + 3) * n..(k + 4) * n];
                for ((((o, &v0), &v1), &v2), &v3) in
                    orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                {
                    *o += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                }
                k += 4;
            }
            while k < k1 {
                let av = arow[k];
                let brow = &b[k * n..(k + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
                k += 1;
            }
        }
    }
}

/// Rows `i0..` of `A^T @ B` into `out_block` (`A` is `m x ka`, `B` is
/// `m x n`).  The reduction runs over the `m` shared rows, unrolled 4-wide;
/// per output element the r contributions are consumed in ascending order in
/// fixed groups of four.
fn t_gemm_rows(
    a: &[f32],
    ka: usize,
    b: &[f32],
    n: usize,
    m: usize,
    i0: usize,
    out_block: &mut [f32],
) {
    out_block.fill(0.0);
    if n == 0 {
        return;
    }
    let rows = out_block.len() / n;
    let mut r = 0;
    while r + 4 <= m {
        for i in 0..rows {
            let c = i0 + i;
            let a0 = a[r * ka + c];
            let a1 = a[(r + 1) * ka + c];
            let a2 = a[(r + 2) * ka + c];
            let a3 = a[(r + 3) * ka + c];
            let orow = &mut out_block[i * n..(i + 1) * n];
            let b0 = &b[r * n..(r + 1) * n];
            let b1 = &b[(r + 1) * n..(r + 2) * n];
            let b2 = &b[(r + 2) * n..(r + 3) * n];
            let b3 = &b[(r + 3) * n..(r + 4) * n];
            for ((((o, &v0), &v1), &v2), &v3) in orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3) {
                *o += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
            }
        }
        r += 4;
    }
    while r < m {
        for i in 0..rows {
            let av = a[r * ka + i0 + i];
            let orow = &mut out_block[i * n..(i + 1) * n];
            let brow = &b[r * n..(r + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
        r += 1;
    }
}

/// Rows `r0..` of `A @ B^T` into `out_block` (`A` is `m x k_dim`, `B` is
/// `b_rows x k_dim`).  Each output element is a dot product computed with 4
/// independent accumulators (breaks the FP add dependency chain so the inner
/// loop pipelines/vectorizes).
fn gemm_t_rows(
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    b_rows: usize,
    r0: usize,
    out_block: &mut [f32],
) {
    if b_rows == 0 {
        return;
    }
    let rows = out_block.len() / b_rows;
    for i in 0..rows {
        let arow = &a[(r0 + i) * k_dim..(r0 + i) * k_dim + k_dim];
        let orow = &mut out_block[i * b_rows..(i + 1) * b_rows];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k_dim..(j + 1) * k_dim];
            let mut acc = [0.0f32; 4];
            let mut chunks_a = arow.chunks_exact(4);
            let mut chunks_b = brow.chunks_exact(4);
            for (ca, cb) in (&mut chunks_a).zip(&mut chunks_b) {
                acc[0] += ca[0] * cb[0];
                acc[1] += ca[1] * cb[1];
                acc[2] += ca[2] * cb[2];
                acc[3] += ca[3] * cb[3];
            }
            let mut s = acc[0] + acc[1] + acc[2] + acc[3];
            for (x, y) in chunks_a.remainder().iter().zip(chunks_b.remainder()) {
                s += x * y;
            }
            *o = s;
        }
    }
}

/// Dense row-major f32 matrix — the crate's workhorse tensor type.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, length rows * cols.
    pub data: Vec<f32>,
}

impl Mat {
    /// A rows x cols matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap an existing row-major buffer (length must be rows * cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from row vectors (all must have equal length).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Element (i, j).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Mutable element (i, j).
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape in place, reusing the allocation (no reallocation once the
    /// capacity has grown to the steady-state shape).
    pub fn reshape_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `src`, reusing the allocation.
    pub fn copy_from_mat(&mut self, src: &Mat) {
        self.reshape_to(src.rows, src.cols);
        self.data.copy_from_slice(&src.data);
    }

    /// out = self @ other. Accumulates into a fresh matrix.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// out = self @ other, writing into a preallocated buffer (hot path —
    /// avoids allocation in the agent optimization loop).  Dispatches to the
    /// row-parallel path for large shapes; bit-exact for any worker count.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        let workers = gemm_workers(self.rows * self.cols * other.cols);
        self.matmul_into_threaded(other, out, workers);
    }

    /// `matmul_into` with an explicit worker count (1 = serial).  Exposed so
    /// tests and benches can assert thread-count determinism directly.
    /// Dispatches to the active SIMD ISA (`tensor::simd`); bit-identical to
    /// the scalar kernel for any ISA and worker count.
    pub fn matmul_into_threaded(&self, other: &Mat, out: &mut Mat, workers: usize) {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        out.reshape_to(self.rows, other.cols);
        let (k, n) = (self.cols, other.cols);
        let isa = simd::dispatch(simd::Kernel::GemmF32);
        parallel_row_blocks(&mut out.data, self.rows, workers, |r0, block| {
            simd::gemm_rows(isa, &self.data, k, &other.data, n, r0, block);
        });
    }

    /// self^T @ other (used for weight gradients: X^T dY).
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols, other.cols);
        self.t_matmul_into(other, &mut out);
        out
    }

    /// self^T @ other into a preallocated buffer.
    pub fn t_matmul_into(&self, other: &Mat, out: &mut Mat) {
        let workers = gemm_workers(self.rows * self.cols * other.cols);
        self.t_matmul_into_threaded(other, out, workers);
    }

    /// `t_matmul_into` with an explicit worker count (1 = serial).
    pub fn t_matmul_into_threaded(&self, other: &Mat, out: &mut Mat, workers: usize) {
        assert_eq!(self.rows, other.rows, "t_matmul outer dim");
        out.reshape_to(self.cols, other.cols);
        let (ka, n, m) = (self.cols, other.cols, self.rows);
        let isa = simd::dispatch(simd::Kernel::TGemmF32);
        parallel_row_blocks(&mut out.data, self.cols, workers, |i0, block| {
            simd::t_gemm_rows(isa, &self.data, ka, &other.data, n, m, i0, block);
        });
    }

    /// self @ other^T (used for input gradients: dY W^T).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.rows);
        self.matmul_t_into(other, &mut out);
        out
    }

    /// self @ other^T into a preallocated buffer.
    pub fn matmul_t_into(&self, other: &Mat, out: &mut Mat) {
        let workers = gemm_workers(self.rows * self.cols * other.rows);
        self.matmul_t_into_threaded(other, out, workers);
    }

    /// `matmul_t_into` with an explicit worker count (1 = serial).
    pub fn matmul_t_into_threaded(&self, other: &Mat, out: &mut Mat, workers: usize) {
        assert_eq!(self.cols, other.cols, "matmul_t inner dim");
        out.reshape_to(self.rows, other.rows);
        let (k, b_rows) = (self.cols, other.rows);
        let isa = simd::dispatch(simd::Kernel::GemmTF32);
        parallel_row_blocks(&mut out.data, self.rows, workers, |r0, block| {
            simd::gemm_t_rows(isa, &self.data, k, &other.data, b_rows, r0, block);
        });
    }

    /// Add a row vector to every row (bias broadcast).
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (x, b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Column sums (bias gradient).
    pub fn col_sum(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        self.col_sum_into(&mut out);
        out
    }

    /// Column sums into a preallocated buffer.
    pub fn col_sum_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for i in 0..self.rows {
            for (o, x) in out.iter_mut().zip(self.row(i)) {
                *o += x;
            }
        }
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    /// Horizontal concatenation [self | other] (critic input: state ++ action).
    pub fn hcat(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        self.hcat_into(other, &mut out);
        out
    }

    /// [self | other] into a preallocated buffer.
    pub fn hcat_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, other.rows);
        out.reshape_to(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            let row = out.row_mut(i);
            row[..self.cols].copy_from_slice(self.row(i));
            row[self.cols..].copy_from_slice(other.row(i));
        }
    }

    /// Split columns at `at`, returning (left, right). Inverse of hcat.
    pub fn hsplit(&self, at: usize) -> (Mat, Mat) {
        assert!(at <= self.cols);
        let mut l = Mat::zeros(self.rows, at);
        let mut r = Mat::zeros(self.rows, self.cols - at);
        for i in 0..self.rows {
            l.row_mut(i).copy_from_slice(&self.row(i)[..at]);
            r.row_mut(i).copy_from_slice(&self.row(i)[at..]);
        }
        (l, r)
    }

    /// Copy columns `[at, cols)` into `out` (the right half of `hsplit`,
    /// without materializing the left half).
    pub fn split_right_into(&self, at: usize, out: &mut Mat) {
        assert!(at <= self.cols);
        out.reshape_to(self.rows, self.cols - at);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[at..]);
        }
    }

    /// Multiply every element by `s`.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Mean of all elements (0 for an empty matrix).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_known() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn t_matmul_equals_explicit_transpose() {
        let a = m(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[1., 0., 0., 1., 1., 1.]);
        // a^T b
        let c = a.t_matmul(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 2);
        assert_eq!(c.data, vec![1. + 5., 3. + 5., 2. + 6., 4. + 6.]);
    }

    #[test]
    fn matmul_t_matches() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(2, 3, &[1., 1., 1., 2., 0., 1.]);
        let c = a.matmul_t(&b); // 2x2
        assert_eq!(c.data, vec![6., 5., 15., 14.]);
    }

    #[test]
    fn bias_and_colsum() {
        let mut a = m(2, 2, &[1., 2., 3., 4.]);
        a.add_row(&[10., 20.]);
        assert_eq!(a.data, vec![11., 22., 13., 24.]);
        assert_eq!(a.col_sum(), vec![24., 46.]);
    }

    #[test]
    fn hcat_hsplit_roundtrip() {
        let a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(2, 1, &[5., 6.]);
        let c = a.hcat(&b);
        assert_eq!(c.cols, 3);
        let (l, r) = c.hsplit(2);
        assert_eq!(l, a);
        assert_eq!(r, b);
        let mut right = Mat::zeros(0, 0);
        c.split_right_into(2, &mut right);
        assert_eq!(right, b);
    }

    #[test]
    fn map_and_hadamard() {
        let a = m(1, 3, &[-1., 0., 2.]);
        let relu = a.map(|x| x.max(0.0));
        assert_eq!(relu.data, vec![0., 0., 2.]);
        let h = a.hadamard(&relu);
        assert_eq!(h.data, vec![0., 0., 4.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(3, 2, &[0.; 6]);
        let _ = a.matmul(&b);
    }

    #[test]
    fn reshape_reuses_allocation() {
        let mut a = Mat::zeros(8, 8);
        let cap = a.data.capacity();
        let ptr = a.data.as_ptr();
        a.reshape_to(4, 4);
        a.reshape_to(8, 8);
        assert_eq!(a.data.capacity(), cap);
        assert_eq!(a.data.as_ptr(), ptr);
        assert_eq!((a.rows, a.cols), (8, 8));
    }

    /// Thread-count determinism on shapes that cross the k-panel and the
    /// unroll remainders (k = 1, 3, KC, KC + 5).
    #[test]
    fn threaded_kernels_bit_exact_vs_serial() {
        let mut rng = crate::util::rng::Pcg64::new(42);
        for &(rows, k, n) in &[(7usize, 1usize, 5usize), (5, 3, 9), (3, 256, 4), (9, 261, 6)] {
            let mut a = Mat::zeros(rows, k);
            let mut b = Mat::zeros(k, n);
            let mut bt = Mat::zeros(n, k);
            let mut c = Mat::zeros(rows, n);
            for x in a
                .data
                .iter_mut()
                .chain(&mut b.data)
                .chain(&mut bt.data)
                .chain(&mut c.data)
            {
                *x = rng.normal() as f32;
            }
            for workers in [2usize, 3, 8] {
                let mut s = Mat::zeros(0, 0);
                let mut p = Mat::zeros(0, 0);
                a.matmul_into_threaded(&b, &mut s, 1);
                a.matmul_into_threaded(&b, &mut p, workers);
                assert_eq!(s.data, p.data, "matmul {rows}x{k}x{n} w={workers}");
                a.t_matmul_into_threaded(&c, &mut s, 1);
                a.t_matmul_into_threaded(&c, &mut p, workers);
                assert_eq!(s.data, p.data, "t_matmul {rows}x{k}x{n} w={workers}");
                a.matmul_t_into_threaded(&bt, &mut s, 1);
                a.matmul_t_into_threaded(&bt, &mut p, workers);
                assert_eq!(s.data, p.data, "matmul_t {rows}x{k}x{n} w={workers}");
            }
        }
    }

    /// The dispatch-threshold fix: a GEMM at or just above `par_min_macs`
    /// goes straight to two workers (the old formula kept everything in
    /// `[t, 2t)` serial), and the threshold follows the tile config.
    #[test]
    fn gemm_workers_crossover_uses_tile_config() {
        if num_threads() < 2 {
            return; // GALEN_NUM_THREADS=1: everything is serial by design
        }
        let _g = simd::TEST_GLOBALS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = simd::tile_config();
        let t = 1 << 20;
        simd::set_tile_config(simd::TileConfig { par_min_macs: t, ..prev });
        assert_eq!(gemm_workers(t - 1), 1, "below threshold stays serial");
        assert_eq!(gemm_workers(t), 2, "crossover goes parallel immediately");
        assert_eq!(gemm_workers(2 * t - 1), 2);
        assert!(gemm_workers(64 * t) <= num_threads());
        simd::set_tile_config(prev);
    }

    #[test]
    fn degenerate_shapes_do_not_panic() {
        let a = Mat::zeros(0, 4);
        let b = Mat::zeros(4, 3);
        assert_eq!(a.matmul(&b).data.len(), 0);
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 2);
        let c = a.matmul(&b); // inner dim 0: all-zero result
        assert!(c.data.iter().all(|&x| x == 0.0));
        assert_eq!((c.rows, c.cols), (3, 2));
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(3, 0);
        let c = a.matmul(&b);
        assert_eq!((c.rows, c.cols), (2, 0));
    }
}
