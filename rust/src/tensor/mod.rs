//! Minimal dense f32 matrix used by the agent networks (rust/src/nn).
//!
//! Row-major `Mat` with exactly the operations DDPG needs: GEMM (with
//! optional transposes), broadcast row ops, elementwise maps.  The GEMM is
//! the L3 hot path (profiled in rust/benches/hot_paths.rs) — it is written
//! as an i-k-j loop over row-major data so the inner loop is a contiguous
//! axpy the compiler auto-vectorizes.

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// out = self @ other. Accumulates into a fresh matrix.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// out = self @ other, writing into a preallocated buffer (hot path —
    /// avoids allocation in the agent optimization loop).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, other.cols);
        out.data.fill(0.0);
        let n = other.cols;
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (k, &a) in arow.iter().enumerate() {
                let brow = &other.data[k * n..(k + 1) * n];
                // zip elides bounds checks; the contiguous axpy vectorizes
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
    }

    /// self^T @ other (used for weight gradients: X^T dY).
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul outer dim");
        let mut out = Mat::zeros(self.cols, other.cols);
        let n = other.cols;
        for r in 0..self.rows {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &a) in arow.iter().enumerate() {
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self @ other^T (used for input gradients: dY W^T).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t inner dim");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.rows {
                let brow = other.row(j);
                // 4 independent accumulators: breaks the FP add dependency
                // chain so the dot product pipelines/vectorizes
                let mut acc = [0.0f32; 4];
                let mut chunks_a = arow.chunks_exact(4);
                let mut chunks_b = brow.chunks_exact(4);
                for (ca, cb) in (&mut chunks_a).zip(&mut chunks_b) {
                    acc[0] += ca[0] * cb[0];
                    acc[1] += ca[1] * cb[1];
                    acc[2] += ca[2] * cb[2];
                    acc[3] += ca[3] * cb[3];
                }
                let mut s = acc[0] + acc[1] + acc[2] + acc[3];
                for (a, b) in chunks_a.remainder().iter().zip(chunks_b.remainder()) {
                    s += a * b;
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    /// Add a row vector to every row (bias broadcast).
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (x, b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Column sums (bias gradient).
    pub fn col_sum(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (o, x) in out.iter_mut().zip(self.row(i)) {
                *o += x;
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    /// Horizontal concatenation [self | other] (critic input: state ++ action).
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let cols = self.cols + other.cols;
        let mut data = Vec::with_capacity(self.rows * cols);
        for i in 0..self.rows {
            data.extend_from_slice(self.row(i));
            data.extend_from_slice(other.row(i));
        }
        Mat {
            rows: self.rows,
            cols,
            data,
        }
    }

    /// Split columns at `at`, returning (left, right). Inverse of hcat.
    pub fn hsplit(&self, at: usize) -> (Mat, Mat) {
        assert!(at <= self.cols);
        let mut l = Mat::zeros(self.rows, at);
        let mut r = Mat::zeros(self.rows, self.cols - at);
        for i in 0..self.rows {
            l.row_mut(i).copy_from_slice(&self.row(i)[..at]);
            r.row_mut(i).copy_from_slice(&self.row(i)[at..]);
        }
        (l, r)
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_known() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn t_matmul_equals_explicit_transpose() {
        let a = m(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[1., 0., 0., 1., 1., 1.]);
        // a^T b
        let c = a.t_matmul(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 2);
        assert_eq!(c.data, vec![1. + 5., 3. + 5., 2. + 6., 4. + 6.]);
    }

    #[test]
    fn matmul_t_matches() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(2, 3, &[1., 1., 1., 2., 0., 1.]);
        let c = a.matmul_t(&b); // 2x2
        assert_eq!(c.data, vec![6., 5., 15., 14.]);
    }

    #[test]
    fn bias_and_colsum() {
        let mut a = m(2, 2, &[1., 2., 3., 4.]);
        a.add_row(&[10., 20.]);
        assert_eq!(a.data, vec![11., 22., 13., 24.]);
        assert_eq!(a.col_sum(), vec![24., 46.]);
    }

    #[test]
    fn hcat_hsplit_roundtrip() {
        let a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(2, 1, &[5., 6.]);
        let c = a.hcat(&b);
        assert_eq!(c.cols, 3);
        let (l, r) = c.hsplit(2);
        assert_eq!(l, a);
        assert_eq!(r, b);
    }

    #[test]
    fn map_and_hadamard() {
        let a = m(1, 3, &[-1., 0., 2.]);
        let relu = a.map(|x| x.max(0.0));
        assert_eq!(relu.data, vec![0., 0., 2.]);
        let h = a.hadamard(&relu);
        assert_eq!(h.data, vec![0., 0., 4.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(3, 2, &[0.; 6]);
        let _ = a.matmul(&b);
    }
}
