//! Quantized integer GEMM kernels: the executable substrate of the
//! measured-latency profiler (`hw::profiler`).
//!
//! Symmetric i8 quantization — per tensor for activations (dynamic range is
//! recomputed every call, like the deployed runtime's dynamic quantize) and
//! per output channel for weights (computed once, offline).  The integer
//! kernel accumulates i8 x i8 products in i32 and applies the
//! `a_scale * w_scale[channel]` epilogue into f32, so a quantized layer can
//! actually *run* and be timed, not just costed analytically.
//!
//! Two integer paths share the PR-1 cache-blocked structure (`KC` k-panels,
//! 4-wide unrolled inner loops, ascending fixed-order accumulation):
//!
//! * `gemm_i8` — unpacked row-major RHS, the drop-in analogue of
//!   `Mat::matmul_into`;
//! * `gemm_i8_packed` — RHS pre-packed into 4-row interleaved k-panels
//!   (`PackedRhsI8`), so the inner loop reads each output column's four
//!   k-contributions from contiguous bytes.  Packing is an offline weight
//!   transformation, exactly like TVM's bit-serial weight pre-packing.
//!
//! Accumulator safety: |q| <= 127, so one product is <= 16129 and a k-deep
//! sum fits i32 for any k < 2^31 / 16129 ≈ 133k — far beyond any layer here.
//!
//! Both integer entries dispatch through `tensor::simd` (AVX2/NEON when
//! detected, `GALEN_SIMD` to override); the scalar cores stay verbatim as
//! the `*_scalar` oracles.  Integer accumulation is associative, so every
//! ISA returns the identical `out` — equality, not tolerance.

use super::{Mat, KC};

/// Symmetric scale for values in [-max_abs, max_abs] onto [-qmax, qmax].
/// An all-zero tensor gets scale 1.0 (every value quantizes to 0).
fn scale_for_qmax(max_abs: f32, qmax: i32) -> f32 {
    if max_abs == 0.0 {
        1.0
    } else {
        max_abs / qmax as f32
    }
}

/// The i8 special case (`qmax = 127`) used by the activation path.
fn scale_for(max_abs: f32) -> f32 {
    scale_for_qmax(max_abs, 127)
}

fn quantize_slice(src: &[f32], scale: f32, dst: &mut [i8]) {
    let inv = 1.0 / scale;
    for (q, &x) in dst.iter_mut().zip(src) {
        *q = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
}

/// Per-tensor symmetrically quantized activation matrix (row-major).
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major i8 values.
    pub data: Vec<i8>,
    /// The single symmetric scale (x ~= q * scale).
    pub scale: f32,
}

impl QuantizedTensor {
    /// Dynamic-range quantize: scan for max |x|, then round-to-nearest.
    pub fn quantize(m: &Mat) -> Self {
        let mut q = Self {
            rows: 0,
            cols: 0,
            data: Vec::new(),
            scale: 1.0,
        };
        q.requantize(m);
        q
    }

    /// Re-quantize in place, reusing the allocation (the per-call dynamic
    /// quantize of the profiler's timed region).
    pub fn requantize(&mut self, m: &Mat) {
        self.rows = m.rows;
        self.cols = m.cols;
        self.data.resize(m.data.len(), 0);
        let max_abs = m.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        self.scale = scale_for(max_abs);
        quantize_slice(&m.data, self.scale, &mut self.data);
    }

    /// Back to f32 (q * scale), for parity tests.
    pub fn dequantize(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&q| q as f32 * self.scale).collect(),
        }
    }
}

/// Per-output-channel symmetrically quantized weight matrix (row-major,
/// columns are output channels — the GEMM RHS layout).
#[derive(Clone, Debug)]
pub struct QuantizedMat {
    /// Row count (the GEMM k dimension).
    pub rows: usize,
    /// Column count (output channels).
    pub cols: usize,
    /// Row-major i8 values.
    pub data: Vec<i8>,
    /// One scale per column (output channel).
    pub scales: Vec<f32>,
}

impl QuantizedMat {
    /// Symmetric per-output-channel quantization (offline weight path).
    pub fn quantize_per_channel(m: &Mat) -> Self {
        Self::quantize_per_channel_qmax(m, 127)
    }

    /// Per-output-channel quantization onto a narrower symmetric grid
    /// `[-qmax, qmax]` — the artifact packer's sub-8-bit weight path
    /// (e.g. `qmax = 7` for 4-bit mixed-precision weights).  Values still
    /// live in i8 storage; only the grid shrinks.
    pub fn quantize_per_channel_qmax(m: &Mat, qmax: i32) -> Self {
        assert!((1..=127).contains(&qmax), "qmax must be in 1..=127");
        let mut max_abs = vec![0.0f32; m.cols];
        for i in 0..m.rows {
            for (mx, &x) in max_abs.iter_mut().zip(m.row(i)) {
                *mx = mx.max(x.abs());
            }
        }
        let scales: Vec<f32> = max_abs
            .into_iter()
            .map(|mx| scale_for_qmax(mx, qmax))
            .collect();
        let lim = qmax as f32;
        let mut data = vec![0i8; m.data.len()];
        for i in 0..m.rows {
            let row = m.row(i);
            let qrow = &mut data[i * m.cols..(i + 1) * m.cols];
            for ((q, &x), &s) in qrow.iter_mut().zip(row).zip(&scales) {
                *q = (x / s).round().clamp(-lim, lim) as i8;
            }
        }
        Self {
            rows: m.rows,
            cols: m.cols,
            data,
            scales,
        }
    }

    /// Back to f32 (q * per-column scale), for parity tests.
    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let qrow = &self.data[i * self.cols..(i + 1) * self.cols];
            let orow = out.row_mut(i);
            for ((o, &q), &s) in orow.iter_mut().zip(qrow).zip(&self.scales) {
                *o = q as f32 * s;
            }
        }
        out
    }

    /// Pre-pack into the 4-row interleaved panel layout (offline weight
    /// transformation for the packed GEMM path).
    pub fn pack(&self) -> PackedRhsI8 {
        PackedRhsI8::pack(&self.data, self.rows, self.cols, self.scales.clone())
    }
}

/// RHS packed for `gemm_i8_packed`: k-panels of 4 rows, columns interleaved
/// so the 4 k-contributions of one output column are contiguous.  Tail rows
/// (k % 4) are zero-padded — zeros are exact no-ops for the accumulation.
///
/// Layout: `data[panel * 4n + j * 4 + r] = rhs[(4*panel + r) * n + j]`.
#[derive(Clone, Debug)]
pub struct PackedRhsI8 {
    /// Logical row count of the unpacked RHS.
    pub k: usize,
    /// Column count (output channels).
    pub n: usize,
    /// The interleaved panel storage.
    pub data: Vec<i8>,
    /// Per-column scales carried along from the quantized weights.
    pub scales: Vec<f32>,
}

impl PackedRhsI8 {
    /// Pack a row-major k x n i8 RHS into the panel layout.
    pub fn pack(rhs: &[i8], k: usize, n: usize, scales: Vec<f32>) -> Self {
        assert_eq!(rhs.len(), k * n, "rhs shape mismatch");
        assert_eq!(scales.len(), n, "one scale per column");
        let panels = k.div_ceil(4).max(1);
        let mut data = vec![0i8; panels * 4 * n];
        for p in 0..panels {
            let panel = &mut data[p * 4 * n..(p + 1) * 4 * n];
            for (j, chunk) in panel.chunks_exact_mut(4).enumerate() {
                for (r, slot) in chunk.iter_mut().enumerate() {
                    let row = 4 * p + r;
                    if row < k {
                        *slot = rhs[row * n + j];
                    }
                }
            }
        }
        Self { k, n, data, scales }
    }
}

/// Integer core: `out[m x n] = a[m x k] @ b[k x n]` in i32, row-major i8
/// operands.  Dispatches to the active SIMD ISA (`tensor::simd`); integer
/// accumulation is exact, so every ISA produces the identical result.
pub fn gemm_i8_i32(a: &[i8], k: usize, b: &[i8], n: usize, out: &mut [i32]) {
    let isa = super::simd::dispatch(super::simd::Kernel::GemmI8);
    super::simd::gemm_i8_i32(isa, a, k, b, n, out);
}

/// Scalar oracle of [`gemm_i8_i32`]: same i-k-j loop, `KC` k-panels and
/// 4-wide unroll as the f32 `gemm_rows` kernel; per output element the k
/// contributions accumulate in ascending order in fixed groups of four.
pub(crate) fn gemm_i8_i32_scalar(a: &[i8], k: usize, b: &[i8], n: usize, out: &mut [i32]) {
    out.fill(0);
    if n == 0 || k == 0 {
        return;
    }
    let m = out.len() / n;
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k..i * k + k];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut kk = k0;
            while kk + 4 <= k1 {
                let a0 = arow[kk] as i32;
                let a1 = arow[kk + 1] as i32;
                let a2 = arow[kk + 2] as i32;
                let a3 = arow[kk + 3] as i32;
                let b0 = &b[kk * n..(kk + 1) * n];
                let b1 = &b[(kk + 1) * n..(kk + 2) * n];
                let b2 = &b[(kk + 2) * n..(kk + 3) * n];
                let b3 = &b[(kk + 3) * n..(kk + 4) * n];
                for ((((o, &v0), &v1), &v2), &v3) in
                    orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                {
                    *o += a0 * v0 as i32 + a1 * v1 as i32 + a2 * v2 as i32 + a3 * v3 as i32;
                }
                kk += 4;
            }
            while kk < k1 {
                let av = arow[kk] as i32;
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv as i32;
                }
                kk += 1;
            }
        }
    }
}

/// Integer core over a packed RHS: bit-identical to `gemm_i8_i32` on the
/// same logical operands (zero-padded tail rows contribute nothing).
/// Dispatches to the active SIMD ISA (`tensor::simd`).
pub fn gemm_i8_packed_i32(a: &[i8], k: usize, packed: &PackedRhsI8, out: &mut [i32]) {
    assert_eq!(packed.k, k, "packed k mismatch");
    let isa = super::simd::dispatch(super::simd::Kernel::GemmI8Packed);
    super::simd::gemm_i8_packed_i32(isa, a, k, packed, out);
}

/// Scalar oracle of [`gemm_i8_packed_i32`].
pub(crate) fn gemm_i8_packed_i32_scalar(a: &[i8], k: usize, packed: &PackedRhsI8, out: &mut [i32]) {
    assert_eq!(packed.k, k, "packed k mismatch");
    let n = packed.n;
    out.fill(0);
    if n == 0 || k == 0 {
        return;
    }
    let m = out.len() / n;
    let panels = k.div_ceil(4);
    for i in 0..m {
        let arow = &a[i * k..i * k + k];
        let orow = &mut out[i * n..(i + 1) * n];
        for p in 0..panels {
            let k0 = 4 * p;
            let a0 = arow[k0] as i32;
            let a1 = if k0 + 1 < k { arow[k0 + 1] as i32 } else { 0 };
            let a2 = if k0 + 2 < k { arow[k0 + 2] as i32 } else { 0 };
            let a3 = if k0 + 3 < k { arow[k0 + 3] as i32 } else { 0 };
            let panel = &packed.data[p * 4 * n..(p + 1) * 4 * n];
            for (o, q) in orow.iter_mut().zip(panel.chunks_exact(4)) {
                *o += a0 * q[0] as i32 + a1 * q[1] as i32 + a2 * q[2] as i32 + a3 * q[3] as i32;
            }
        }
    }
}

/// Quantized GEMM with f32 epilogue: `out = (qa @ qw) * a_scale * w_scale[j]`.
/// `acc` is the caller-owned i32 accumulator (reused across calls — the
/// profiler's timed region allocates nothing).
pub fn gemm_i8(a: &QuantizedTensor, w: &QuantizedMat, acc: &mut Vec<i32>, out: &mut Mat) {
    assert_eq!(a.cols, w.rows, "gemm_i8 inner dim");
    let (m, n) = (a.rows, w.cols);
    acc.clear();
    acc.resize(m * n, 0);
    gemm_i8_i32(&a.data, a.cols, &w.data, n, acc);
    scale_epilogue(acc, a.scale, &w.scales, m, n, out);
}

/// Packed-RHS variant of `gemm_i8` (same result, packed inner loop).
pub fn gemm_i8_packed(a: &QuantizedTensor, w: &PackedRhsI8, acc: &mut Vec<i32>, out: &mut Mat) {
    assert_eq!(a.cols, w.k, "gemm_i8_packed inner dim");
    let (m, n) = (a.rows, w.n);
    acc.clear();
    acc.resize(m * n, 0);
    gemm_i8_packed_i32(&a.data, a.cols, w, acc);
    scale_epilogue(acc, a.scale, &w.scales, m, n, out);
}

fn scale_epilogue(acc: &[i32], a_scale: f32, w_scales: &[f32], m: usize, n: usize, out: &mut Mat) {
    out.reshape_to(m, n);
    for i in 0..m {
        let arow = &acc[i * n..(i + 1) * n];
        let orow = out.row_mut(i);
        for ((o, &q), &s) in orow.iter_mut().zip(arow).zip(w_scales) {
            *o = q as f32 * a_scale * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize, amp: f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for x in &mut m.data {
            *x = (rng.next_f32() * 2.0 - 1.0) * amp;
        }
        m
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Pcg64::new(11);
        let m = random_mat(&mut rng, 9, 13, 4.0);
        let q = QuantizedTensor::quantize(&m);
        let back = q.dequantize();
        let half = q.scale * 0.5 * 1.0001;
        for (x, y) in m.data.iter().zip(&back.data) {
            assert!((x - y).abs() <= half, "{x} vs {y} (scale {})", q.scale);
        }
    }

    #[test]
    fn per_channel_roundtrip_error_bounded_per_column() {
        let mut rng = Pcg64::new(12);
        let mut m = random_mat(&mut rng, 8, 6, 1.0);
        // give columns wildly different ranges: per-channel scales must adapt
        for i in 0..m.rows {
            for j in 0..m.cols {
                *m.at_mut(i, j) *= (j + 1) as f32 * 10.0;
            }
        }
        let q = QuantizedMat::quantize_per_channel(&m);
        let back = q.dequantize();
        for i in 0..m.rows {
            for j in 0..m.cols {
                let tol = q.scales[j] * 0.5 * 1.0001;
                let (x, y) = (m.at(i, j), back.at(i, j));
                assert!((x - y).abs() <= tol, "[{i},{j}] {x} vs {y}");
            }
        }
    }

    #[test]
    fn qmax_grid_bounds_values_and_error() {
        let mut rng = Pcg64::new(13);
        let m = random_mat(&mut rng, 12, 5, 3.0);
        for qmax in [1i32, 7, 31, 127] {
            let q = QuantizedMat::quantize_per_channel_qmax(&m, qmax);
            assert!(
                q.data.iter().all(|&v| (v as i32).abs() <= qmax),
                "values escape the ±{qmax} grid"
            );
            let back = q.dequantize();
            for j in 0..m.cols {
                let tol = q.scales[j] * 0.5 * 1.0001;
                for i in 0..m.rows {
                    assert!((m.at(i, j) - back.at(i, j)).abs() <= tol, "qmax {qmax} [{i},{j}]");
                }
            }
        }
        // the default path is exactly the 127 grid
        let a = QuantizedMat::quantize_per_channel(&m);
        let b = QuantizedMat::quantize_per_channel_qmax(&m, 127);
        assert_eq!(a.data, b.data);
        assert_eq!(a.scales, b.scales);
    }

    #[test]
    fn zero_tensor_quantizes_cleanly() {
        let m = Mat::zeros(3, 4);
        let q = QuantizedTensor::quantize(&m);
        assert_eq!(q.scale, 1.0);
        assert!(q.data.iter().all(|&v| v == 0));
        let qm = QuantizedMat::quantize_per_channel(&m);
        assert!(qm.scales.iter().all(|&s| s == 1.0));
        assert_eq!(qm.dequantize(), m);
    }

    #[test]
    fn integer_gemm_known_values() {
        // 2x3 @ 3x2 with small integers: exact check against hand result
        let a: Vec<i8> = vec![1, 2, 3, 4, 5, 6];
        let b: Vec<i8> = vec![7, 8, 9, 10, 11, 12];
        let mut out = vec![0i32; 4];
        gemm_i8_i32(&a, 3, &b, 2, &mut out);
        assert_eq!(out, vec![58, 64, 139, 154]);
    }

    #[test]
    fn packed_matches_unpacked_across_tail_shapes() {
        let mut rng = Pcg64::new(21);
        // k crosses the 4-wide unroll tail (1, 3) and the KC panel (256+5)
        for &(m, k, n) in &[(3usize, 1usize, 5usize), (4, 3, 2), (2, 261, 7), (5, 8, 1)] {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let packed = PackedRhsI8::pack(&b, k, n, vec![1.0; n]);
            let mut flat = vec![0i32; m * n];
            let mut pk = vec![0i32; m * n];
            gemm_i8_i32(&a, k, &b, n, &mut flat);
            gemm_i8_packed_i32(&a, k, &packed, &mut pk);
            assert_eq!(flat, pk, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn scaled_gemm_matches_f32_on_dequantized_operands() {
        // The quantized GEMM is *exactly* the f32 GEMM of the dequantized
        // operands (integer accumulation is exact; the epilogue applies the
        // scales).  Compare against Mat::matmul of the dequantized matrices.
        let mut rng = Pcg64::new(31);
        let a = random_mat(&mut rng, 6, 10, 2.0);
        let w = random_mat(&mut rng, 10, 5, 0.5);
        let qa = QuantizedTensor::quantize(&a);
        let qw = QuantizedMat::quantize_per_channel(&w);
        let reference = qa.dequantize().matmul(&qw.dequantize());

        let mut acc = Vec::new();
        let mut out = Mat::zeros(0, 0);
        gemm_i8(&qa, &qw, &mut acc, &mut out);
        for (x, y) in out.data.iter().zip(&reference.data) {
            assert!((x - y).abs() <= 1e-4 * y.abs().max(1.0), "{x} vs {y}");
        }

        let mut out2 = Mat::zeros(0, 0);
        gemm_i8_packed(&qa, &qw.pack(), &mut acc, &mut out2);
        assert_eq!(out.data, out2.data, "packed epilogue must be bit-equal");
    }

    #[test]
    fn requantize_reuses_allocation() {
        let mut rng = Pcg64::new(41);
        let m = random_mat(&mut rng, 8, 8, 1.0);
        let mut q = QuantizedTensor::quantize(&m);
        let ptr = q.data.as_ptr();
        let m2 = random_mat(&mut rng, 8, 8, 3.0);
        q.requantize(&m2);
        assert_eq!(q.data.as_ptr(), ptr);
        assert!(q.scale > 0.0);
    }
}
