//! Depthwise convolution kernels (f32 and quantized i8): the executable
//! substrate for MobileNet-style workloads in `hw::MeasuredProfiler`.
//!
//! A depthwise conv applies one `k x k` filter per channel — no cross-channel
//! reduction — so it does *not* lower to the im2col GEMM the dense layers
//! use.  These kernels run the windowed per-channel dot products directly,
//! channel-major (`[c][y][x]`), with the same conventions as the GEMM
//! substrate in this module's siblings:
//!
//! * zero padding of `kernel / 2` on each side, matching the spatial
//!   schedule of the model IR (`out = in / stride` for odd kernels);
//! * f32 and i8 paths compute each output element's contributions in the
//!   identical fixed (ky, kx) order, so the i8 kernel is *exactly* the f32
//!   kernel of the dequantized operands (integer accumulation is exact,
//!   the per-channel scale epilogue is one multiply) — the property the
//!   parity tests in `rust/tests/prop_depthwise.rs` pin down;
//! * accumulator safety: |q| <= 127, so a k x k window sum fits i32 for any
//!   kernel under ~133k taps — far beyond any depthwise layer here.

use super::Mat;

/// Per-channel symmetrically quantized depthwise filter bank
/// (`[c][ky][kx]`, one scale per channel — the offline weight path).
#[derive(Clone, Debug)]
pub struct QuantizedDwWeights {
    /// Channel count.
    pub channels: usize,
    /// Square kernel extent.
    pub kernel: usize,
    /// Channel-major i8 taps, length `channels * kernel * kernel`.
    pub data: Vec<i8>,
    /// One symmetric scale per channel (w ~= q * scale).
    pub scales: Vec<f32>,
}

impl QuantizedDwWeights {
    /// Quantize a channel-major f32 filter bank per channel.
    pub fn quantize(weights: &[f32], channels: usize, kernel: usize) -> Self {
        assert_eq!(weights.len(), channels * kernel * kernel, "filter bank shape");
        let taps = kernel * kernel;
        let mut data = vec![0i8; weights.len()];
        let mut scales = vec![1.0f32; channels];
        for c in 0..channels {
            let w = &weights[c * taps..(c + 1) * taps];
            let max_abs = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let scale = if max_abs == 0.0 { 1.0 } else { max_abs / 127.0 };
            scales[c] = scale;
            let q = &mut data[c * taps..(c + 1) * taps];
            for (qi, &x) in q.iter_mut().zip(w) {
                *qi = (x / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        Self {
            channels,
            kernel,
            data,
            scales,
        }
    }

    /// Back to f32 (q * per-channel scale), for parity tests.
    pub fn dequantize(&self) -> Vec<f32> {
        let taps = self.kernel * self.kernel;
        self.data
            .iter()
            .enumerate()
            .map(|(i, &q)| q as f32 * self.scales[i / taps])
            .collect()
    }
}

/// f32 depthwise conv: `input` is `[channels][in_sp][in_sp]`, `weights`
/// `[channels][kernel][kernel]`, `out` `[channels][out_sp][out_sp]` — all
/// channel-major, zero-padded by `kernel / 2`.
///
/// Determinism contract: per output element the (ky, kx) taps accumulate in
/// ascending fixed order (shared with the i8 kernel).  Dispatches to the
/// active SIMD ISA (`tensor::simd`) at stride 1 — SIMD output is
/// bit-identical to the scalar oracle; other strides always run scalar.
#[allow(clippy::too_many_arguments)]
pub fn conv_dw_f32(
    input: &[f32],
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    kernel: usize,
    stride: usize,
    weights: &[f32],
    out: &mut [f32],
) {
    let isa = super::simd::dispatch(super::simd::Kernel::DwF32);
    super::simd::conv_dw_f32(
        isa, input, channels, in_sp, out_sp, kernel, stride, weights, out,
    );
}

/// Scalar oracle of [`conv_dw_f32`] (also the path for strides != 1).
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_dw_f32_scalar(
    input: &[f32],
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    kernel: usize,
    stride: usize,
    weights: &[f32],
    out: &mut [f32],
) {
    assert_eq!(input.len(), channels * in_sp * in_sp, "input shape");
    assert_eq!(weights.len(), channels * kernel * kernel, "weight shape");
    assert_eq!(out.len(), channels * out_sp * out_sp, "output shape");
    let pad = kernel / 2;
    for c in 0..channels {
        let plane = &input[c * in_sp * in_sp..(c + 1) * in_sp * in_sp];
        let w = &weights[c * kernel * kernel..(c + 1) * kernel * kernel];
        let oplane = &mut out[c * out_sp * out_sp..(c + 1) * out_sp * out_sp];
        for oy in 0..out_sp {
            for ox in 0..out_sp {
                let mut acc = 0.0f32;
                for ky in 0..kernel {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= in_sp as isize {
                        continue;
                    }
                    let row = &plane[iy as usize * in_sp..(iy as usize + 1) * in_sp];
                    let wrow = &w[ky * kernel..(ky + 1) * kernel];
                    for kx in 0..kernel {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= in_sp as isize {
                            continue;
                        }
                        acc += row[ix as usize] * wrow[kx];
                    }
                }
                oplane[oy * out_sp + ox] = acc;
            }
        }
    }
}

/// Quantized depthwise conv with f32 epilogue:
/// `out = (q_in (*) q_w) * a_scale * w_scale[c]` — i8 taps accumulated in
/// i32 per output element (exact), scales applied once per element.  Taps
/// visit the identical (ky, kx) order as [`conv_dw_f32`], so the result is
/// exactly the f32 conv of the dequantized operands.  Dispatches to the
/// active SIMD ISA (`tensor::simd`) at stride 1 (exact — integer
/// accumulation); other strides always run scalar.
#[allow(clippy::too_many_arguments)]
pub fn conv_dw_i8(
    input: &[i8],
    a_scale: f32,
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    stride: usize,
    w: &QuantizedDwWeights,
    out: &mut [f32],
) {
    let isa = super::simd::dispatch(super::simd::Kernel::DwI8);
    super::simd::conv_dw_i8(
        isa, input, a_scale, channels, in_sp, out_sp, stride, w, out,
    );
}

/// Scalar oracle of [`conv_dw_i8`] (also the path for strides != 1).
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_dw_i8_scalar(
    input: &[i8],
    a_scale: f32,
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    stride: usize,
    w: &QuantizedDwWeights,
    out: &mut [f32],
) {
    assert_eq!(w.channels, channels, "filter bank channels");
    assert_eq!(input.len(), channels * in_sp * in_sp, "input shape");
    assert_eq!(out.len(), channels * out_sp * out_sp, "output shape");
    let kernel = w.kernel;
    let pad = kernel / 2;
    for c in 0..channels {
        let plane = &input[c * in_sp * in_sp..(c + 1) * in_sp * in_sp];
        let taps = &w.data[c * kernel * kernel..(c + 1) * kernel * kernel];
        let scale = a_scale * w.scales[c];
        let oplane = &mut out[c * out_sp * out_sp..(c + 1) * out_sp * out_sp];
        for oy in 0..out_sp {
            for ox in 0..out_sp {
                let mut acc = 0i32;
                for ky in 0..kernel {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= in_sp as isize {
                        continue;
                    }
                    let row = &plane[iy as usize * in_sp..(iy as usize + 1) * in_sp];
                    let wrow = &taps[ky * kernel..(ky + 1) * kernel];
                    for kx in 0..kernel {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= in_sp as isize {
                            continue;
                        }
                        acc += row[ix as usize] as i32 * wrow[kx] as i32;
                    }
                }
                oplane[oy * out_sp + ox] = acc as f32 * scale;
            }
        }
    }
}

/// Convenience wrapper over [`conv_dw_f32`] for `Mat` activations laid out
/// as `channels x (sp * sp)` (one spatial plane per row).
pub fn conv_dw_f32_mat(
    input: &Mat,
    in_sp: usize,
    out_sp: usize,
    kernel: usize,
    stride: usize,
    weights: &[f32],
    out: &mut Mat,
) {
    assert_eq!(input.cols, in_sp * in_sp, "one spatial plane per row");
    out.reshape_to(input.rows, out_sp * out_sp);
    conv_dw_f32(
        &input.data,
        input.rows,
        in_sp,
        out_sp,
        kernel,
        stride,
        weights,
        &mut out.data,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::QuantizedTensor;
    use crate::util::rng::Pcg64;

    fn random(rng: &mut Pcg64, n: usize, amp: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * amp).collect()
    }

    #[test]
    fn identity_kernel_passes_input_through() {
        // 1x1 kernel with weight 1.0 at stride 1 is the identity
        let (c, sp) = (3, 4);
        let mut rng = Pcg64::new(5);
        let input = random(&mut rng, c * sp * sp, 1.0);
        let weights = vec![1.0f32; c];
        let mut out = vec![0.0f32; c * sp * sp];
        conv_dw_f32(&input, c, sp, sp, 1, 1, &weights, &mut out);
        assert_eq!(out, input);
    }

    #[test]
    fn known_3x3_window_sum() {
        // single channel, 3x3 input of ones, 3x3 filter of ones: the center
        // output sees all 9 taps, corners see 4 (zero padding)
        let input = vec![1.0f32; 9];
        let weights = vec![1.0f32; 9];
        let mut out = vec![0.0f32; 9];
        conv_dw_f32(&input, 1, 3, 3, 3, 1, &weights, &mut out);
        assert_eq!(out[4], 9.0);
        assert_eq!(out[0], 4.0);
        assert_eq!(out[2], 4.0);
        assert_eq!(out[1], 6.0);
    }

    #[test]
    fn stride_two_halves_the_grid() {
        let (c, in_sp, out_sp) = (2, 8, 4);
        let mut rng = Pcg64::new(7);
        let input = random(&mut rng, c * in_sp * in_sp, 1.0);
        let weights = random(&mut rng, c * 9, 0.5);
        let mut out = vec![0.0f32; c * out_sp * out_sp];
        conv_dw_f32(&input, c, in_sp, out_sp, 3, 2, &weights, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        // strided output (0,0) = full conv output (0,0)
        let mut full = vec![0.0f32; c * in_sp * in_sp];
        conv_dw_f32(&input, c, in_sp, in_sp, 3, 1, &weights, &mut full);
        assert_eq!(out[0], full[0]);
        // strided (oy, ox) samples the stride-2 grid of the full output
        assert_eq!(out[1], full[2]);
        assert_eq!(out[out_sp], full[2 * in_sp]);
    }

    #[test]
    fn i8_matches_f32_of_dequantized_operands() {
        let (c, in_sp, out_sp, k, stride) = (5, 6, 3, 3, 2);
        let mut rng = Pcg64::new(11);
        let input = Mat::from_vec(c, in_sp * in_sp, random(&mut rng, c * in_sp * in_sp, 2.0));
        let weights = random(&mut rng, c * k * k, 0.8);
        let qa = QuantizedTensor::quantize(&input);
        let qw = QuantizedDwWeights::quantize(&weights, c, k);

        let mut qout = vec![0.0f32; c * out_sp * out_sp];
        conv_dw_i8(&qa.data, qa.scale, c, in_sp, out_sp, stride, &qw, &mut qout);

        let mut reference = vec![0.0f32; c * out_sp * out_sp];
        conv_dw_f32(
            &qa.dequantize().data,
            c,
            in_sp,
            out_sp,
            k,
            stride,
            &qw.dequantize(),
            &mut reference,
        );
        for (x, y) in qout.iter().zip(&reference) {
            assert!((x - y).abs() <= 1e-4 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn weight_quantization_roundtrip_bounded_per_channel() {
        let mut rng = Pcg64::new(13);
        let (c, k) = (4, 3);
        let mut w = random(&mut rng, c * k * k, 1.0);
        // wildly different per-channel ranges
        for ci in 0..c {
            for t in 0..k * k {
                w[ci * k * k + t] *= (ci + 1) as f32 * 10.0;
            }
        }
        let q = QuantizedDwWeights::quantize(&w, c, k);
        let back = q.dequantize();
        for (i, (x, y)) in w.iter().zip(&back).enumerate() {
            let tol = q.scales[i / (k * k)] * 0.5 * 1.0001;
            assert!((x - y).abs() <= tol, "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn mat_wrapper_reshapes_output() {
        let (c, sp) = (2, 4);
        let mut rng = Pcg64::new(17);
        let input = Mat::from_vec(c, sp * sp, random(&mut rng, c * sp * sp, 1.0));
        let weights = random(&mut rng, c * 9, 1.0);
        let mut out = Mat::zeros(0, 0);
        conv_dw_f32_mat(&input, sp, sp / 2, 3, 2, &weights, &mut out);
        assert_eq!((out.rows, out.cols), (c, 4));
    }
}
