//! Runtime-dispatched SIMD kernels with a scalar bit-exactness oracle.
//!
//! Every hot kernel in `tensor` (the f32 GEMM family, the i8×i8→i32 GEMMs,
//! and the depthwise convolutions) dispatches through this module: at each
//! public kernel entry the active ISA is resolved once
//! ([`dispatch`] — AVX2 on x86_64, NEON on aarch64, detected at runtime via
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!`) and the
//! per-row kernel body runs either the scalar implementation (the verbatim
//! PR-1 loops, kept as the correctness oracle) or the `std::arch` SIMD
//! variant in [`avx2`] / [`neon`].
//!
//! ## The bit-exactness invariant
//!
//! The f32 SIMD kernels vectorize **across the `n`/output-column dimension
//! only** and use separate multiply + add instructions (never FMA): each
//! SIMD lane computes exactly the scalar per-element expression
//! `*o += a0*v0 + a1*v1 + a2*v2 + a3*v3` in the same ascending-k
//! groups-of-four order, so SIMD output is **bit-identical** to scalar by
//! construction.  Vectorizing the k-reduction instead (or letting the
//! compiler contract to FMA) would reassociate the float sum and change
//! low-order bits — which would silently shift golden trajectories,
//! `.galen` artifact bytes, and every N-thread == 1-thread fence.  The i8
//! kernels accumulate in i32, where addition *is* associative, so their
//! reductions vectorize freely (`_mm256_madd_epi16` pair-sums, NEON
//! widening multiply-accumulates) — order-exactness is automatic.
//!
//! Depthwise convolutions vectorize across the output-x dimension at
//! stride 1 (each lane keeps the scalar ascending (ky, kx) tap order);
//! other strides fall back to the scalar kernels.
//!
//! ## Mode override and observability
//!
//! `GALEN_SIMD=off|scalar|auto` selects the dispatch mode process-wide
//! (`off` and `scalar` both force the scalar oracle; `auto`, the default,
//! uses the best detected ISA).  [`set_mode`] overrides it at runtime for
//! tests and benches.  Every dispatch increments
//! `simd_dispatch_total{path,isa}` in the metrics registry — inert like all
//! obs counters: results are bit-identical with metrics on or off.
//!
//! ## Tile configuration and autotuning
//!
//! The SIMD kernels read their blocking parameters from a process-wide
//! [`TileConfig`] (k-panel height `kc`, row sub-block `mc`, and the
//! parallel-dispatch threshold `par_min_macs` consumed by
//! `tensor::gemm_workers`).  [`autotune`] sweeps a small candidate grid at
//! first profiler use and `hw::MeasuredProfiler` persists the winner into
//! the versioned profile cache next to the target fingerprint, so later
//! runs re-tune nothing.  Any `kc` that is a multiple of 4 preserves the
//! scalar grouping (panel boundaries stay 4-aligned, the remainder loop is
//! only ever the final `k % 4` tail), so tuning never affects results.

/// AVX2 (x86_64) kernel bodies.
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
/// NEON (aarch64) kernel bodies.
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
/// The tile-parameter autotuner.
mod tune;

pub use tune::{autotune, autotune_runs};

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::obs;

/// Dispatch mode: which kernel family the process runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Force the scalar oracle kernels (`GALEN_SIMD=off` / `=scalar`).
    Scalar,
    /// Use the best runtime-detected ISA (`GALEN_SIMD=auto`, the default).
    Auto,
}

/// The instruction set a kernel call actually runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// The scalar oracle (also the fallback when no SIMD ISA is detected).
    Scalar,
    /// 256-bit AVX2 (x86_64, runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 128-bit NEON (aarch64, runtime-detected).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

// Mode cell: 0 = Scalar, 1 = Auto, 0xFF = not yet initialized from the
// environment.  A plain atomic (not OnceLock) so tests and benches can
// flip the mode at runtime; the env parse races benignly (idempotent).
static MODE: AtomicU8 = AtomicU8::new(0xFF);

fn mode_from_env() -> SimdMode {
    match std::env::var("GALEN_SIMD").ok().as_deref() {
        Some("off") | Some("scalar") => SimdMode::Scalar,
        Some("auto") | None => SimdMode::Auto,
        Some(other) => {
            log::warn!("GALEN_SIMD={other:?} not recognized (off|scalar|auto); using auto");
            SimdMode::Auto
        }
    }
}

/// The current dispatch mode (initialized from `GALEN_SIMD` on first use).
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        0 => SimdMode::Scalar,
        1 => SimdMode::Auto,
        _ => {
            let m = mode_from_env();
            MODE.store(if m == SimdMode::Scalar { 0 } else { 1 }, Ordering::Relaxed);
            m
        }
    }
}

/// Override the dispatch mode process-wide (tests / benches; production
/// uses the `GALEN_SIMD` environment variable).  Because SIMD output is
/// bit-identical to scalar, flipping the mode never changes results — only
/// which kernel bodies produce them.
pub fn set_mode(m: SimdMode) {
    MODE.store(if m == SimdMode::Scalar { 0 } else { 1 }, Ordering::Relaxed);
}

/// Best ISA the host supports (runtime feature detection, cached).
fn detected_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Isa::Neon;
            }
        }
        Isa::Scalar
    })
}

/// The ISA kernel calls dispatch to under the current mode.
pub fn active_isa() -> Isa {
    match mode() {
        SimdMode::Scalar => Isa::Scalar,
        SimdMode::Auto => detected_isa(),
    }
}

/// Metrics label of [`active_isa`] (`"scalar"`, `"avx2"`, `"neon"`).
pub fn isa_label() -> &'static str {
    match active_isa() {
        Isa::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => "avx2",
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => "neon",
    }
}

/// Label of the SIMD ISA this build *could* dispatch to (independent of
/// runtime detection and mode) — the non-scalar column of the dispatch
/// counter.
const SIMD_LABEL: &str = {
    #[cfg(target_arch = "x86_64")]
    {
        "avx2"
    }
    #[cfg(target_arch = "aarch64")]
    {
        "neon"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "none"
    }
};

/// Kernel families that dispatch through this module (the `path` label of
/// `simd_dispatch_total`).
#[derive(Clone, Copy, Debug)]
pub(crate) enum Kernel {
    /// `Mat::matmul_into` (`A @ B`).
    GemmF32,
    /// `Mat::t_matmul_into` (`A^T @ B`).
    TGemmF32,
    /// `Mat::matmul_t_into` (`A @ B^T`).
    GemmTF32,
    /// `quant::gemm_i8_i32` (unpacked RHS).
    GemmI8,
    /// `quant::gemm_i8_packed_i32` (panel-packed RHS).
    GemmI8Packed,
    /// `depthwise::conv_dw_f32`.
    DwF32,
    /// `depthwise::conv_dw_i8`.
    DwI8,
}

const KERNELS: usize = 7;

impl Kernel {
    fn label(self) -> &'static str {
        match self {
            Kernel::GemmF32 => "gemm_f32",
            Kernel::TGemmF32 => "t_gemm_f32",
            Kernel::GemmTF32 => "gemm_t_f32",
            Kernel::GemmI8 => "gemm_i8",
            Kernel::GemmI8Packed => "gemm_i8_packed",
            Kernel::DwF32 => "dw_f32",
            Kernel::DwI8 => "dw_i8",
        }
    }
}

const KERNEL_LABELS: [&str; KERNELS] = [
    "gemm_f32",
    "t_gemm_f32",
    "gemm_t_f32",
    "gemm_i8",
    "gemm_i8_packed",
    "dw_f32",
    "dw_i8",
];

/// One registered counter per (path, isa) pair, built eagerly on first
/// dispatch so the hot path is a relaxed sharded add.
fn dispatch_counter(k: Kernel, isa: Isa) -> &'static obs::Counter {
    static C: OnceLock<Vec<obs::Counter>> = OnceLock::new();
    let all = C.get_or_init(|| {
        let mut v = Vec::with_capacity(KERNELS * 2);
        for path in KERNEL_LABELS {
            for isa_label in ["scalar", SIMD_LABEL] {
                v.push(obs::Counter::register(
                    "simd_dispatch_total",
                    &[("path", path), ("isa", isa_label)],
                ));
            }
        }
        v
    });
    let isa_ix = usize::from(isa != Isa::Scalar);
    &all[kernel_index(k) * 2 + isa_ix]
}

fn kernel_index(k: Kernel) -> usize {
    match k {
        Kernel::GemmF32 => 0,
        Kernel::TGemmF32 => 1,
        Kernel::GemmTF32 => 2,
        Kernel::GemmI8 => 3,
        Kernel::GemmI8Packed => 4,
        Kernel::DwF32 => 5,
        Kernel::DwI8 => 6,
    }
}

/// Resolve the ISA for one kernel call and count the dispatch
/// (`simd_dispatch_total{path,isa}`).  Called once per public kernel entry
/// — not per row block — so the counter tracks kernel calls, not the
/// worker split.
pub(crate) fn dispatch(k: Kernel) -> Isa {
    let isa = active_isa();
    dispatch_counter(k, isa).inc();
    isa
}

// ---------------------------------------------------------------------------
// Tile configuration
// ---------------------------------------------------------------------------

/// Blocking parameters of the SIMD kernels plus the parallel-dispatch
/// threshold, autotuned per target and persisted in the profile cache.
///
/// Every field is results-neutral by construction: `kc` is clamped to a
/// multiple of 4 so the scalar groups-of-four accumulation boundaries are
/// preserved, `mc` only reorders whole disjoint output rows, and
/// `par_min_macs` only moves the serial/parallel worker crossover (the
/// row-parallel path is bit-identical at any worker count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    /// K-panel height of the blocked SIMD GEMMs (multiple of 4).
    pub kc: usize,
    /// Row sub-block height inside a k-panel (cache blocking of the
    /// output/LHS rows); large values disable sub-blocking.
    pub mc: usize,
    /// Minimum MAC count before a GEMM fans out to the row-parallel path
    /// (consumed by `tensor::gemm_workers`).
    pub par_min_macs: usize,
}

impl TileConfig {
    /// The untuned defaults: the scalar kernels' historical constants
    /// (`KC = 256`, no row sub-blocking, `PAR_MIN_MACS = 2^21`).
    pub fn untuned() -> Self {
        Self { kc: 256, mc: 1 << 20, par_min_macs: 1 << 21 }
    }

    /// Clamp fields to their validity domains (`kc` to a positive multiple
    /// of 4, `mc`/`par_min_macs` to >= 1).
    pub fn sanitized(self) -> Self {
        Self {
            kc: (self.kc & !3).max(4),
            mc: self.mc.max(1),
            par_min_macs: self.par_min_macs.max(1),
        }
    }
}

/// Serializes tests that mutate the process-wide tile config or dispatch
/// mode (the parallel test runner would otherwise interleave them).
#[cfg(test)]
pub(crate) static TEST_GLOBALS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

static TILE_KC: AtomicUsize = AtomicUsize::new(256);
static TILE_MC: AtomicUsize = AtomicUsize::new(1 << 20);
static TILE_PAR_MIN: AtomicUsize = AtomicUsize::new(1 << 21);

/// The process-wide tile configuration the kernels currently read.
pub fn tile_config() -> TileConfig {
    TileConfig {
        kc: TILE_KC.load(Ordering::Relaxed),
        mc: TILE_MC.load(Ordering::Relaxed),
        par_min_macs: TILE_PAR_MIN.load(Ordering::Relaxed),
    }
}

/// Install a tile configuration process-wide (sanitized; see
/// [`TileConfig::sanitized`]).  Called by `hw::MeasuredProfiler` with the
/// autotuned (or cache-loaded) config; never changes kernel results.
pub fn set_tile_config(t: TileConfig) {
    let t = t.sanitized();
    TILE_KC.store(t.kc, Ordering::Relaxed);
    TILE_MC.store(t.mc, Ordering::Relaxed);
    TILE_PAR_MIN.store(t.par_min_macs, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Kernel dispatch wrappers (one per family; scalar fallback inline)
// ---------------------------------------------------------------------------

/// Rows `r0..` of `A @ B` under `isa` (bit-identical to the scalar
/// `tensor::gemm_rows` for every ISA).
pub(crate) fn gemm_rows(
    isa: Isa,
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    n: usize,
    r0: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => super::gemm_rows(a, k_dim, b, n, r0, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            let t = tile_config();
            unsafe { avx2::gemm_rows(a, k_dim, b, n, r0, out, t.kc, t.mc) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            let t = tile_config();
            unsafe { neon::gemm_rows(a, k_dim, b, n, r0, out, t.kc, t.mc) }
        }
    }
}

/// [`gemm_rows`] with explicit tile parameters (the autotuner's probe
/// entry; the scalar oracle ignores them).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_rows_tiled(
    isa: Isa,
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    n: usize,
    r0: usize,
    out: &mut [f32],
    kc: usize,
    mc: usize,
) {
    match isa {
        Isa::Scalar => super::gemm_rows(a, k_dim, b, n, r0, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::gemm_rows(a, k_dim, b, n, r0, out, kc, mc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::gemm_rows(a, k_dim, b, n, r0, out, kc, mc) },
    }
}

/// Rows `i0..` of `A^T @ B` under `isa` (bit-identical to the scalar
/// `tensor::t_gemm_rows`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn t_gemm_rows(
    isa: Isa,
    a: &[f32],
    ka: usize,
    b: &[f32],
    n: usize,
    m: usize,
    i0: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => super::t_gemm_rows(a, ka, b, n, m, i0, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::t_gemm_rows(a, ka, b, n, m, i0, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::t_gemm_rows(a, ka, b, n, m, i0, out) },
    }
}

/// Rows `r0..` of `A @ B^T` under `isa` (bit-identical to the scalar
/// `tensor::gemm_t_rows`).
pub(crate) fn gemm_t_rows(
    isa: Isa,
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    b_rows: usize,
    r0: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => super::gemm_t_rows(a, k_dim, b, b_rows, r0, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::gemm_t_rows(a, k_dim, b, b_rows, r0, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::gemm_t_rows(a, k_dim, b, b_rows, r0, out) },
    }
}

/// i8×i8→i32 GEMM under `isa` (integer accumulation: equal to scalar for
/// every ISA, with a freely vectorized reduction).
pub(crate) fn gemm_i8_i32(isa: Isa, a: &[i8], k: usize, b: &[i8], n: usize, out: &mut [i32]) {
    match isa {
        Isa::Scalar => super::quant::gemm_i8_i32_scalar(a, k, b, n, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            let kc = tile_config().kc;
            unsafe { avx2::gemm_i8_i32(a, k, b, n, out, kc) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            let kc = tile_config().kc;
            unsafe { neon::gemm_i8_i32(a, k, b, n, out, kc) }
        }
    }
}

/// Panel-packed i8×i8→i32 GEMM under `isa` (equal to [`gemm_i8_i32`] on
/// the same logical operands).
pub(crate) fn gemm_i8_packed_i32(
    isa: Isa,
    a: &[i8],
    k: usize,
    packed: &super::quant::PackedRhsI8,
    out: &mut [i32],
) {
    match isa {
        Isa::Scalar => super::quant::gemm_i8_packed_i32_scalar(a, k, packed, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::gemm_i8_packed_i32(a, k, &packed.data, packed.n, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::gemm_i8_packed_i32(a, k, &packed.data, packed.n, out) },
    }
}

/// f32 depthwise conv under `isa`.  SIMD vectorizes the output-x dimension
/// at stride 1 (bit-identical per element: ascending (ky, kx) tap order is
/// preserved lane-wise); other strides run the scalar oracle.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_dw_f32(
    isa: Isa,
    input: &[f32],
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    kernel: usize,
    stride: usize,
    weights: &[f32],
    out: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if stride == 1 => unsafe {
            avx2::conv_dw_f32(input, channels, in_sp, out_sp, kernel, weights, out)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if stride == 1 => unsafe {
            neon::conv_dw_f32(input, channels, in_sp, out_sp, kernel, weights, out)
        },
        _ => super::depthwise::conv_dw_f32_scalar(
            input, channels, in_sp, out_sp, kernel, stride, weights, out,
        ),
    }
}

/// i8 depthwise conv under `isa` (i32 accumulation; stride 1 vectorizes,
/// other strides run the scalar oracle).  `acc` is a caller-owned i32
/// scratch row reused across calls.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_dw_i8(
    isa: Isa,
    input: &[i8],
    a_scale: f32,
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    stride: usize,
    w: &super::depthwise::QuantizedDwWeights,
    out: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if stride == 1 => unsafe {
            avx2::conv_dw_i8(input, a_scale, channels, in_sp, out_sp, w, out)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if stride == 1 => unsafe {
            neon::conv_dw_i8(input, a_scale, channels, in_sp, out_sp, w, out)
        },
        _ => super::depthwise::conv_dw_i8_scalar(
            input, a_scale, channels, in_sp, out_sp, stride, w, out,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    /// The module's core promise, asserted at the row-kernel level across
    /// shapes that cross every vector-width and unroll tail: the SIMD f32
    /// kernels are bit-identical to the scalar oracle.
    #[test]
    fn simd_f32_row_kernels_match_scalar_bit_exact() {
        let isa = detected_isa();
        let mut rng = Pcg64::new(0x51);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (2, 4, 8),
            (4, 261, 9),
            (5, 16, 17),
            (3, 300, 31),
            (2, 7, 33),
        ] {
            let a = random_f32(&mut rng, m * k);
            let b = random_f32(&mut rng, k * n);
            let bt = random_f32(&mut rng, n * k);
            let c = random_f32(&mut rng, m * n);
            let mut s = vec![0.0f32; m * n];
            let mut v = vec![0.0f32; m * n];
            gemm_rows(Isa::Scalar, &a, k, &b, n, 0, &mut s);
            gemm_rows(isa, &a, k, &b, n, 0, &mut v);
            assert_eq!(s, v, "gemm_rows {m}x{k}x{n}");

            let mut st = vec![0.0f32; k * n];
            let mut vt = vec![0.0f32; k * n];
            t_gemm_rows(Isa::Scalar, &a, k, &c, n, m, 0, &mut st);
            t_gemm_rows(isa, &a, k, &c, n, m, 0, &mut vt);
            assert_eq!(st, vt, "t_gemm_rows {m}x{k}x{n}");

            let mut sg = vec![0.0f32; m * n];
            let mut vg = vec![0.0f32; m * n];
            gemm_t_rows(Isa::Scalar, &a, k, &bt, n, 0, &mut sg);
            gemm_t_rows(isa, &a, k, &bt, n, 0, &mut vg);
            assert_eq!(sg, vg, "gemm_t_rows {m}x{k}x{n}");
        }
    }

    /// Tile parameters never change f32 results (kc stays 4-aligned).
    #[test]
    fn tile_parameters_are_results_neutral() {
        let isa = detected_isa();
        let mut rng = Pcg64::new(0x52);
        let (m, k, n) = (5usize, 261usize, 19usize);
        let a = random_f32(&mut rng, m * k);
        let b = random_f32(&mut rng, k * n);
        let mut reference = vec![0.0f32; m * n];
        gemm_rows_tiled(isa, &a, k, &b, n, 0, &mut reference, 256, 1 << 20);
        for &(kc, mc) in &[(4usize, 1usize), (128, 2), (512, 3), (8, 1 << 20)] {
            let mut out = vec![0.0f32; m * n];
            gemm_rows_tiled(isa, &a, k, &b, n, 0, &mut out, kc, mc);
            assert_eq!(reference, out, "kc={kc} mc={mc}");
        }
    }

    #[test]
    fn tile_config_roundtrip_and_sanitization() {
        let _g = TEST_GLOBALS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = tile_config();
        set_tile_config(TileConfig { kc: 130, mc: 0, par_min_macs: 0 });
        let t = tile_config();
        assert_eq!(t.kc, 128, "kc clamps to a multiple of 4");
        assert_eq!(t.mc, 1);
        assert_eq!(t.par_min_macs, 1);
        set_tile_config(prev);
        assert_eq!(tile_config(), prev.sanitized());
    }

    #[test]
    fn untuned_defaults_match_the_historical_constants() {
        let t = TileConfig::untuned();
        assert_eq!(t.kc, 256);
        assert_eq!(t.par_min_macs, 1 << 21);
    }

    #[test]
    fn mode_controls_active_isa() {
        let _g = TEST_GLOBALS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = mode();
        set_mode(SimdMode::Scalar);
        assert_eq!(active_isa(), Isa::Scalar);
        assert_eq!(isa_label(), "scalar");
        set_mode(SimdMode::Auto);
        assert_eq!(active_isa(), detected_isa());
        set_mode(prev);
    }

    #[test]
    fn dispatch_counts_into_the_registry() {
        let _g = TEST_GLOBALS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let isa = active_isa();
        let before = dispatch_counter(Kernel::GemmF32, isa).value();
        let _ = dispatch(Kernel::GemmF32);
        let after = dispatch_counter(Kernel::GemmF32, isa).value();
        // >= rather than ==: concurrent tests also run f32 GEMMs and bump
        // the same process-wide counter
        assert!(after >= before + 1, "{after} vs {before}");
    }
}
