//! Tile-parameter autotuner: picks `TileConfig` for this host/target pair.
//!
//! Runs once per profile cache (at `MeasuredProfiler::with_cache` time,
//! when the manifest has no recorded tile yet — the winner is persisted
//! next to the target fingerprint, so second runs re-tune nothing).  The
//! sweep is deliberately tiny (~tens of milliseconds): a fixed probe GEMM
//! is timed over a small candidate grid of (`kc`, `mc`), and the
//! parallel-dispatch threshold is derived from the measured thread-spawn
//! overhead against the probe's MAC rate.
//!
//! Every candidate is results-neutral (`kc` candidates are multiples of 4;
//! see `TileConfig`), so the autotuner can never change what a kernel
//! computes — only how fast it computes it.

use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::{active_isa, gemm_rows_tiled, Isa, TileConfig};
use crate::util::{parallel_row_blocks, rng::Pcg64};

/// Probe GEMM shape: large enough that kc/mc matter, small enough that the
/// whole sweep stays in the tens of milliseconds.
const PROBE_M: usize = 48;
const PROBE_K: usize = 256;
const PROBE_N: usize = 64;

/// Candidate k-panel heights (all multiples of 4 — results-neutral).
const KC_CANDIDATES: [usize; 3] = [128, 256, 512];
/// Candidate row sub-blocks (`1 << 20` disables sub-blocking).
const MC_CANDIDATES: [usize; 3] = [8, 32, 1 << 20];

static RUNS: AtomicU64 = AtomicU64::new(0);

/// How many times [`autotune`] has executed in this process — lets tests
/// (and the profiler smoke) assert the zero-re-tune-on-second-run
/// contract.
pub fn autotune_runs() -> u64 {
    RUNS.load(Ordering::Relaxed)
}

fn median3(mut xs: [f64; 3]) -> f64 {
    xs.sort_by(f64::total_cmp);
    xs[1]
}

fn time_probe(isa: Isa, a: &[f32], b: &[f32], out: &mut [f32], kc: usize, mc: usize) -> f64 {
    // one warmup, then the median of three reps
    gemm_rows_tiled(isa, a, PROBE_K, b, PROBE_N, 0, out, kc, mc);
    let mut reps = [0.0f64; 3];
    for r in &mut reps {
        let t0 = Instant::now();
        gemm_rows_tiled(isa, a, PROBE_K, b, PROBE_N, 0, out, kc, mc);
        black_box(&out[0]);
        *r = t0.elapsed().as_secs_f64();
    }
    median3(reps)
}

/// Measure the round-trip overhead of fanning a trivial workload out to two
/// scoped threads — the cost a parallel GEMM dispatch must amortize.
fn spawn_overhead_s(out: &mut [f32]) -> f64 {
    let rows = PROBE_M;
    let mut reps = [0.0f64; 3];
    for r in &mut reps {
        let t0 = Instant::now();
        parallel_row_blocks(out, rows, 2, |_r0, block| {
            black_box(block.first());
        });
        *r = t0.elapsed().as_secs_f64();
    }
    median3(reps)
}

/// Sweep the candidate grid and derive the parallel-dispatch threshold.
///
/// Under a scalar-only dispatch (mode `off`, or no SIMD ISA detected) the
/// kc/mc sweep is skipped — the scalar oracle ignores tile parameters —
/// but `par_min_macs` is still measured, since the serial/parallel
/// crossover matters for any kernel family.
///
/// The measurement is memoized per process (it probes *host* kernel
/// throughput, which no simulated target changes), so only the first
/// tile-less profile cache in a process pays the sweep; [`autotune_runs`]
/// counts actual measurement runs.
pub fn autotune() -> TileConfig {
    static CACHED: std::sync::OnceLock<TileConfig> = std::sync::OnceLock::new();
    *CACHED.get_or_init(autotune_measured)
}

fn autotune_measured() -> TileConfig {
    RUNS.fetch_add(1, Ordering::Relaxed);
    let isa = active_isa();
    let mut rng = Pcg64::new(0x7e57_7e57);
    let a: Vec<f32> = (0..PROBE_M * PROBE_K).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let b: Vec<f32> = (0..PROBE_K * PROBE_N).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let mut out = vec![0.0f32; PROBE_M * PROBE_N];

    let mut best = TileConfig::untuned();
    let mut best_t = time_probe(isa, &a, &b, &mut out, best.kc, best.mc);
    if isa != Isa::Scalar {
        for &kc in &KC_CANDIDATES {
            for &mc in &MC_CANDIDATES {
                if kc == best.kc && mc == best.mc {
                    continue;
                }
                let t = time_probe(isa, &a, &b, &mut out, kc, mc);
                if t < best_t {
                    best_t = t;
                    best.kc = kc;
                    best.mc = mc;
                }
            }
        }
    }

    // Threshold: the parallel path must buy back ~2x the spawn overhead.
    let macs = (PROBE_M * PROBE_K * PROBE_N) as f64;
    let mac_rate = macs / best_t.max(1e-9);
    let spawn = spawn_overhead_s(&mut out);
    best.par_min_macs = ((2.0 * spawn * mac_rate) as usize).clamp(1 << 18, 1 << 24);
    log::info!(
        "autotuned tiles: kc={} mc={} par_min_macs={} (probe {:.1} GMAC/s, spawn {:.1}us)",
        best.kc,
        best.mc,
        best.par_min_macs,
        mac_rate / 1e9,
        spawn * 1e6
    );
    best.sanitized()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autotune_returns_a_sane_config_and_memoizes() {
        let t = autotune();
        let runs = autotune_runs();
        assert!(runs >= 1);
        let t2 = autotune();
        assert_eq!(autotune_runs(), runs, "second call must be memoized");
        assert_eq!(t, t2);
        assert_eq!(t.kc % 4, 0, "kc must stay a multiple of 4");
        assert!(t.kc >= 4);
        assert!(t.mc >= 1);
        assert!((1 << 18..=1 << 24).contains(&t.par_min_macs));
    }
}
