//! AVX2 kernel bodies (x86_64, runtime-dispatched by `tensor::simd`).
//!
//! Every f32 kernel here mirrors its scalar oracle loop-for-loop: SIMD
//! lanes run across the `n`/output-column dimension, each lane evaluating
//! the scalar per-element expression with separate `_mm256_mul_ps` /
//! `_mm256_add_ps` instructions (no FMA — fusing would skip the
//! intermediate rounding the scalar kernels perform), so results are
//! bit-identical to scalar for every shape.  The i8 kernels accumulate in
//! i32 where addition is associative, so their reductions use the wider
//! tricks (`_mm256_madd_epi16` pair sums) freely — equal to scalar by
//! exact integer arithmetic.
//!
//! Safety: every function is `#[target_feature(enable = "avx2")]` and must
//! only be called after `is_x86_feature_detected!("avx2")` succeeded —
//! `tensor::simd::dispatch` guarantees that.  All loads/stores are
//! unaligned-safe (`loadu`/`storeu`) and stay inside the slice bounds
//! checked by the vector-width guards.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

use super::super::depthwise::QuantizedDwWeights;

/// Rows `r0..` of `A @ B` (bit-identical to `tensor::gemm_rows`), with
/// explicit tile parameters: `kc` k-panels (multiple of 4 — the caller
/// sanitizes) and `mc` row sub-blocks.
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_rows(
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    n: usize,
    r0: usize,
    out_block: &mut [f32],
    kc: usize,
    mc: usize,
) {
    out_block.fill(0.0);
    if n == 0 || k_dim == 0 {
        return;
    }
    let rows = out_block.len() / n;
    for k0 in (0..k_dim).step_by(kc) {
        let k1 = (k0 + kc).min(k_dim);
        for i0 in (0..rows).step_by(mc) {
            let i1 = (i0 + mc).min(rows);
            for i in i0..i1 {
                let arow = &a[(r0 + i) * k_dim..(r0 + i) * k_dim + k_dim];
                let orow = &mut out_block[i * n..(i + 1) * n];
                let op = orow.as_mut_ptr();
                let mut k = k0;
                while k + 4 <= k1 {
                    let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                    let (va0, va1) = (_mm256_set1_ps(a0), _mm256_set1_ps(a1));
                    let (va2, va3) = (_mm256_set1_ps(a2), _mm256_set1_ps(a3));
                    let b0 = b.as_ptr().add(k * n);
                    let b1 = b.as_ptr().add((k + 1) * n);
                    let b2 = b.as_ptr().add((k + 2) * n);
                    let b3 = b.as_ptr().add((k + 3) * n);
                    let mut j = 0;
                    while j + 8 <= n {
                        // (((a0*v0 + a1*v1) + a2*v2) + a3*v3), then o + t:
                        // the scalar expression, lane-for-lane, no FMA.
                        let t = _mm256_add_ps(
                            _mm256_add_ps(
                                _mm256_add_ps(
                                    _mm256_mul_ps(va0, _mm256_loadu_ps(b0.add(j))),
                                    _mm256_mul_ps(va1, _mm256_loadu_ps(b1.add(j))),
                                ),
                                _mm256_mul_ps(va2, _mm256_loadu_ps(b2.add(j))),
                            ),
                            _mm256_mul_ps(va3, _mm256_loadu_ps(b3.add(j))),
                        );
                        _mm256_storeu_ps(op.add(j), _mm256_add_ps(_mm256_loadu_ps(op.add(j)), t));
                        j += 8;
                    }
                    while j < n {
                        *op.add(j) +=
                            a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
                        j += 1;
                    }
                    k += 4;
                }
                while k < k1 {
                    let av = arow[k];
                    let vav = _mm256_set1_ps(av);
                    let bp = b.as_ptr().add(k * n);
                    let mut j = 0;
                    while j + 8 <= n {
                        let t = _mm256_mul_ps(vav, _mm256_loadu_ps(bp.add(j)));
                        _mm256_storeu_ps(op.add(j), _mm256_add_ps(_mm256_loadu_ps(op.add(j)), t));
                        j += 8;
                    }
                    while j < n {
                        *op.add(j) += av * *bp.add(j);
                        j += 1;
                    }
                    k += 1;
                }
            }
        }
    }
}

/// Rows `i0..` of `A^T @ B` (bit-identical to `tensor::t_gemm_rows`).
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn t_gemm_rows(
    a: &[f32],
    ka: usize,
    b: &[f32],
    n: usize,
    m: usize,
    i0: usize,
    out_block: &mut [f32],
) {
    out_block.fill(0.0);
    if n == 0 {
        return;
    }
    let rows = out_block.len() / n;
    let mut r = 0;
    while r + 4 <= m {
        for i in 0..rows {
            let c = i0 + i;
            let (a0, a1) = (a[r * ka + c], a[(r + 1) * ka + c]);
            let (a2, a3) = (a[(r + 2) * ka + c], a[(r + 3) * ka + c]);
            let (va0, va1) = (_mm256_set1_ps(a0), _mm256_set1_ps(a1));
            let (va2, va3) = (_mm256_set1_ps(a2), _mm256_set1_ps(a3));
            let op = out_block.as_mut_ptr().add(i * n);
            let b0 = b.as_ptr().add(r * n);
            let b1 = b.as_ptr().add((r + 1) * n);
            let b2 = b.as_ptr().add((r + 2) * n);
            let b3 = b.as_ptr().add((r + 3) * n);
            let mut j = 0;
            while j + 8 <= n {
                let t = _mm256_add_ps(
                    _mm256_add_ps(
                        _mm256_add_ps(
                            _mm256_mul_ps(va0, _mm256_loadu_ps(b0.add(j))),
                            _mm256_mul_ps(va1, _mm256_loadu_ps(b1.add(j))),
                        ),
                        _mm256_mul_ps(va2, _mm256_loadu_ps(b2.add(j))),
                    ),
                    _mm256_mul_ps(va3, _mm256_loadu_ps(b3.add(j))),
                );
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(_mm256_loadu_ps(op.add(j)), t));
                j += 8;
            }
            while j < n {
                *op.add(j) +=
                    a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
                j += 1;
            }
        }
        r += 4;
    }
    while r < m {
        for i in 0..rows {
            let av = a[r * ka + i0 + i];
            let vav = _mm256_set1_ps(av);
            let op = out_block.as_mut_ptr().add(i * n);
            let bp = b.as_ptr().add(r * n);
            let mut j = 0;
            while j + 8 <= n {
                let t = _mm256_mul_ps(vav, _mm256_loadu_ps(bp.add(j)));
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(_mm256_loadu_ps(op.add(j)), t));
                j += 8;
            }
            while j < n {
                *op.add(j) += av * *bp.add(j);
                j += 1;
            }
        }
        r += 1;
    }
}

/// Rows `r0..` of `A @ B^T` (bit-identical to `tensor::gemm_t_rows`).
///
/// The scalar kernel keeps 4 independent dot-product accumulators over
/// `chunks_exact(4)`; a 128-bit `__m128` maps onto them lane-for-lane
/// (`acc[l] += ca[l] * cb[l]` per lane, mul then add — no FMA), and the
/// horizontal sum extracts the lanes in the scalar's exact
/// `((acc0 + acc1) + acc2) + acc3` order.  256-bit lanes would change the
/// accumulator split, and with it the bits.
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_t_rows(
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    b_rows: usize,
    r0: usize,
    out_block: &mut [f32],
) {
    if b_rows == 0 {
        return;
    }
    let rows = out_block.len() / b_rows;
    for i in 0..rows {
        let arow = &a[(r0 + i) * k_dim..(r0 + i) * k_dim + k_dim];
        let orow = &mut out_block[i * b_rows..(i + 1) * b_rows];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k_dim..(j + 1) * k_dim];
            let mut vacc = _mm_setzero_ps();
            let chunks = k_dim / 4;
            for t in 0..chunks {
                let ca = _mm_loadu_ps(arow.as_ptr().add(4 * t));
                let cb = _mm_loadu_ps(brow.as_ptr().add(4 * t));
                vacc = _mm_add_ps(vacc, _mm_mul_ps(ca, cb));
            }
            let mut acc = [0.0f32; 4];
            _mm_storeu_ps(acc.as_mut_ptr(), vacc);
            let mut s = acc[0] + acc[1] + acc[2] + acc[3];
            for t in 4 * chunks..k_dim {
                s += arow[t] * brow[t];
            }
            *o = s;
        }
    }
}

/// i8×i8→i32 GEMM, unpacked row-major RHS (equal to
/// `quant::gemm_i8_i32_scalar` — integer accumulation is exact, so the
/// vectorized reduction is equality, not just bit-luck).  8 output columns
/// per iteration: sign-extend 8 RHS bytes to i32 lanes, `_mm256_mullo_epi32`
/// against the broadcast LHS value.
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_i8_i32(
    a: &[i8],
    k: usize,
    b: &[i8],
    n: usize,
    out: &mut [i32],
    kc: usize,
) {
    out.fill(0);
    if n == 0 || k == 0 {
        return;
    }
    let m = out.len() / n;
    for k0 in (0..k).step_by(kc) {
        let k1 = (k0 + kc).min(k);
        for i in 0..m {
            let arow = &a[i * k..i * k + k];
            let op = out.as_mut_ptr().add(i * n);
            let mut kk = k0;
            while kk + 4 <= k1 {
                let a0 = arow[kk] as i32;
                let a1 = arow[kk + 1] as i32;
                let a2 = arow[kk + 2] as i32;
                let a3 = arow[kk + 3] as i32;
                let (va0, va1) = (_mm256_set1_epi32(a0), _mm256_set1_epi32(a1));
                let (va2, va3) = (_mm256_set1_epi32(a2), _mm256_set1_epi32(a3));
                let b0 = b.as_ptr().add(kk * n);
                let b1 = b.as_ptr().add((kk + 1) * n);
                let b2 = b.as_ptr().add((kk + 2) * n);
                let b3 = b.as_ptr().add((kk + 3) * n);
                let mut j = 0;
                while j + 8 <= n {
                    let t = _mm256_add_epi32(
                        _mm256_add_epi32(
                            _mm256_mullo_epi32(va0, widen8(b0.add(j))),
                            _mm256_mullo_epi32(va1, widen8(b1.add(j))),
                        ),
                        _mm256_add_epi32(
                            _mm256_mullo_epi32(va2, widen8(b2.add(j))),
                            _mm256_mullo_epi32(va3, widen8(b3.add(j))),
                        ),
                    );
                    let o = op.add(j) as *mut __m256i;
                    _mm256_storeu_si256(o, _mm256_add_epi32(_mm256_loadu_si256(o), t));
                    j += 8;
                }
                while j < n {
                    *op.add(j) += a0 * *b0.add(j) as i32
                        + a1 * *b1.add(j) as i32
                        + a2 * *b2.add(j) as i32
                        + a3 * *b3.add(j) as i32;
                    j += 1;
                }
                kk += 4;
            }
            while kk < k1 {
                let av = arow[kk] as i32;
                let vav = _mm256_set1_epi32(av);
                let bp = b.as_ptr().add(kk * n);
                let mut j = 0;
                while j + 8 <= n {
                    let t = _mm256_mullo_epi32(vav, widen8(bp.add(j)));
                    let o = op.add(j) as *mut __m256i;
                    _mm256_storeu_si256(o, _mm256_add_epi32(_mm256_loadu_si256(o), t));
                    j += 8;
                }
                while j < n {
                    *op.add(j) += av * *bp.add(j) as i32;
                    j += 1;
                }
                kk += 1;
            }
        }
    }
}

/// Sign-extend 8 consecutive i8 values to 8 i32 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn widen8(p: *const i8) -> __m256i {
    _mm256_cvtepi8_epi32(_mm_loadl_epi64(p as *const __m128i))
}

/// i8×i8→i32 GEMM over the 4-row interleaved panel layout of
/// `quant::PackedRhsI8` (equal to `quant::gemm_i8_packed_i32_scalar`).
///
/// A 32-byte load covers 8 output columns × 4 interleaved k-taps; the four
/// LHS taps are packed into the i16 lanes of a broadcast quadword so one
/// `_mm256_madd_epi16` yields per-column pair sums (|i8×i8| ≤ 16129, two of
/// them fit i16-pair madd into i32 exactly), which
/// `_mm256_hadd_epi32` + a 64-bit lane permute fold back into column order.
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_i8_packed_i32(
    a: &[i8],
    k: usize,
    packed: &[i8],
    n: usize,
    out: &mut [i32],
) {
    out.fill(0);
    if n == 0 || k == 0 {
        return;
    }
    let m = out.len() / n;
    let panels = k.div_ceil(4);
    for i in 0..m {
        let arow = &a[i * k..i * k + k];
        let op = out.as_mut_ptr().add(i * n);
        for p in 0..panels {
            let k0 = 4 * p;
            let a0 = arow[k0] as i32;
            let a1 = if k0 + 1 < k { arow[k0 + 1] as i32 } else { 0 };
            let a2 = if k0 + 2 < k { arow[k0 + 2] as i32 } else { 0 };
            let a3 = if k0 + 3 < k { arow[k0 + 3] as i32 } else { 0 };
            // i16 lane pattern [a0, a1, a2, a3] repeated across the vector.
            let pat = (a0 as i16 as u16 as u64)
                | ((a1 as i16 as u16 as u64) << 16)
                | ((a2 as i16 as u16 as u64) << 32)
                | ((a3 as i16 as u16 as u64) << 48);
            let coeff = _mm256_set1_epi64x(pat as i64);
            let panel = packed.as_ptr().add(p * 4 * n);
            let mut j = 0;
            while j + 8 <= n {
                let q = _mm256_loadu_si256(panel.add(j * 4) as *const __m256i);
                let lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(q));
                let hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(q));
                // madd: per column c, lanes hold (q0*a0 + q1*a1) and
                // (q2*a2 + q3*a3); hadd folds the pairs, but interleaves
                // the 128-bit halves: [c0 c1 c4 c5 | c2 c3 c6 c7].
                let plo = _mm256_madd_epi16(lo, coeff);
                let phi = _mm256_madd_epi16(hi, coeff);
                let h = _mm256_hadd_epi32(plo, phi);
                // 64-bit lane permute (0, 2, 1, 3) restores column order.
                let t = _mm256_permute4x64_epi64::<0b11_01_10_00>(h);
                let o = op.add(j) as *mut __m256i;
                _mm256_storeu_si256(o, _mm256_add_epi32(_mm256_loadu_si256(o), t));
                j += 8;
            }
            while j < n {
                let q = panel.add(j * 4);
                *op.add(j) += a0 * *q as i32
                    + a1 * *q.add(1) as i32
                    + a2 * *q.add(2) as i32
                    + a3 * *q.add(3) as i32;
                j += 1;
            }
        }
    }
}

/// f32 depthwise conv at stride 1 (bit-identical to
/// `depthwise::conv_dw_f32_scalar`): the (ky, kx) tap loops move outside
/// the output-x loop, which vectorizes 8-wide — each output element still
/// receives its taps in ascending (ky, kx) order, starting from 0.0, so
/// the f32 sum sequence is exactly the scalar one.
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn conv_dw_f32(
    input: &[f32],
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    kernel: usize,
    weights: &[f32],
    out: &mut [f32],
) {
    assert_eq!(input.len(), channels * in_sp * in_sp, "input shape");
    assert_eq!(weights.len(), channels * kernel * kernel, "weight shape");
    assert_eq!(out.len(), channels * out_sp * out_sp, "output shape");
    let pad = kernel / 2;
    for c in 0..channels {
        let plane = &input[c * in_sp * in_sp..(c + 1) * in_sp * in_sp];
        let w = &weights[c * kernel * kernel..(c + 1) * kernel * kernel];
        let oplane = &mut out[c * out_sp * out_sp..(c + 1) * out_sp * out_sp];
        for oy in 0..out_sp {
            let orow = &mut oplane[oy * out_sp..(oy + 1) * out_sp];
            orow.fill(0.0);
            let op = orow.as_mut_ptr();
            for ky in 0..kernel {
                let iy = (oy + ky) as isize - pad as isize;
                if iy < 0 || iy >= in_sp as isize {
                    continue;
                }
                let row = plane.as_ptr().add(iy as usize * in_sp);
                let wrow = &w[ky * kernel..(ky + 1) * kernel];
                for (kx, &wv) in wrow.iter().enumerate() {
                    // valid ox range: 0 <= ox + kx - pad < in_sp
                    let lo = pad.saturating_sub(kx);
                    let hi = (in_sp + pad).saturating_sub(kx).min(out_sp);
                    if lo >= hi {
                        continue;
                    }
                    let vw = _mm256_set1_ps(wv);
                    let src = row.add(lo + kx - pad);
                    let mut j = lo;
                    while j + 8 <= hi {
                        let t = _mm256_mul_ps(vw, _mm256_loadu_ps(src.add(j - lo)));
                        _mm256_storeu_ps(op.add(j), _mm256_add_ps(_mm256_loadu_ps(op.add(j)), t));
                        j += 8;
                    }
                    while j < hi {
                        *op.add(j) += *src.add(j - lo) * wv;
                        j += 1;
                    }
                }
            }
        }
    }
}

/// i8 depthwise conv at stride 1 (equal to
/// `depthwise::conv_dw_i8_scalar`): groups of 8 output columns accumulate
/// a full (ky, kx) window in i32 register lanes, with the scalar
/// per-element path covering border groups where a tap column would fall
/// outside the input.
///
/// # Safety
/// Requires AVX2 (runtime-detected by the dispatcher).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn conv_dw_i8(
    input: &[i8],
    a_scale: f32,
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    w: &QuantizedDwWeights,
    out: &mut [f32],
) {
    assert_eq!(w.channels, channels, "filter bank channels");
    assert_eq!(input.len(), channels * in_sp * in_sp, "input shape");
    assert_eq!(out.len(), channels * out_sp * out_sp, "output shape");
    let kernel = w.kernel;
    let pad = kernel / 2;
    // interior groups: all kernel columns of all 8 lanes land inside the
    // input row (0 <= ox + kx - pad and ox + 7 + kx - pad < in_sp for all
    // kx in 0..kernel)
    let int_lo = pad;
    let int_hi = (in_sp + pad).saturating_sub(kernel + 6);
    for c in 0..channels {
        let plane = &input[c * in_sp * in_sp..(c + 1) * in_sp * in_sp];
        let taps = &w.data[c * kernel * kernel..(c + 1) * kernel * kernel];
        let scale = a_scale * w.scales[c];
        let oplane = &mut out[c * out_sp * out_sp..(c + 1) * out_sp * out_sp];
        for oy in 0..out_sp {
            let orow = &mut oplane[oy * out_sp..(oy + 1) * out_sp];
            let mut ox = 0;
            while ox < out_sp {
                if ox >= int_lo && ox < int_hi && ox + 8 <= out_sp {
                    let mut vacc = _mm256_setzero_si256();
                    for ky in 0..kernel {
                        let iy = (oy + ky) as isize - pad as isize;
                        if iy < 0 || iy >= in_sp as isize {
                            continue;
                        }
                        let row = plane.as_ptr().add(iy as usize * in_sp);
                        for kx in 0..kernel {
                            let coeff =
                                _mm256_set1_epi32(taps[ky * kernel + kx] as i32);
                            let v = widen8(row.add(ox + kx - pad));
                            vacc = _mm256_add_epi32(vacc, _mm256_mullo_epi32(coeff, v));
                        }
                    }
                    let mut acc = [0i32; 8];
                    _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, vacc);
                    for (l, &q) in acc.iter().enumerate() {
                        orow[ox + l] = q as f32 * scale;
                    }
                    ox += 8;
                } else {
                    // border / tail: the scalar per-element path, verbatim
                    let mut acc = 0i32;
                    for ky in 0..kernel {
                        let iy = (oy + ky) as isize - pad as isize;
                        if iy < 0 || iy >= in_sp as isize {
                            continue;
                        }
                        let row = &plane[iy as usize * in_sp..(iy as usize + 1) * in_sp];
                        let wrow = &taps[ky * kernel..(ky + 1) * kernel];
                        for (kx, &tv) in wrow.iter().enumerate() {
                            let ix = (ox + kx) as isize - pad as isize;
                            if ix < 0 || ix >= in_sp as isize {
                                continue;
                            }
                            acc += row[ix as usize] as i32 * tv as i32;
                        }
                    }
                    orow[ox] = acc as f32 * scale;
                    ox += 1;
                }
            }
        }
    }
}
