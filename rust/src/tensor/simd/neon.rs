//! NEON kernel bodies (aarch64, runtime-dispatched by `tensor::simd`).
//!
//! Mirrors `simd::avx2` at 128-bit width: f32 kernels vectorize across the
//! `n`/output-column dimension with separate `vmulq_f32` / `vaddq_f32`
//! (never `vfmaq`/`vmlaq`, which fuse and change low-order bits), so
//! results are bit-identical to the scalar oracle; i8 kernels use the
//! widening multiply-accumulates (`vmull_n_s16`/`vmlal_n_s16` — exact
//! integer arithmetic) and the stride-4 de-interleaving load `vld4_s8`
//! that matches `PackedRhsI8`'s panel layout directly.
//!
//! Safety: every function is `#[target_feature(enable = "neon")]` and must
//! only be called after `is_aarch64_feature_detected!("neon")` succeeded —
//! `tensor::simd::dispatch` guarantees that.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use super::super::depthwise::QuantizedDwWeights;

/// Rows `r0..` of `A @ B` (bit-identical to `tensor::gemm_rows`), with
/// explicit tile parameters (`kc` a multiple of 4 — the caller sanitizes).
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_rows(
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    n: usize,
    r0: usize,
    out_block: &mut [f32],
    kc: usize,
    mc: usize,
) {
    out_block.fill(0.0);
    if n == 0 || k_dim == 0 {
        return;
    }
    let rows = out_block.len() / n;
    for k0 in (0..k_dim).step_by(kc) {
        let k1 = (k0 + kc).min(k_dim);
        for i0 in (0..rows).step_by(mc) {
            let i1 = (i0 + mc).min(rows);
            for i in i0..i1 {
                let arow = &a[(r0 + i) * k_dim..(r0 + i) * k_dim + k_dim];
                let orow = &mut out_block[i * n..(i + 1) * n];
                let op = orow.as_mut_ptr();
                let mut k = k0;
                while k + 4 <= k1 {
                    let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                    let b0 = b.as_ptr().add(k * n);
                    let b1 = b.as_ptr().add((k + 1) * n);
                    let b2 = b.as_ptr().add((k + 2) * n);
                    let b3 = b.as_ptr().add((k + 3) * n);
                    let mut j = 0;
                    while j + 4 <= n {
                        // (((a0*v0 + a1*v1) + a2*v2) + a3*v3), then o + t —
                        // the scalar expression lane-for-lane, no fused mla.
                        let t = vaddq_f32(
                            vaddq_f32(
                                vaddq_f32(
                                    vmulq_n_f32(vld1q_f32(b0.add(j)), a0),
                                    vmulq_n_f32(vld1q_f32(b1.add(j)), a1),
                                ),
                                vmulq_n_f32(vld1q_f32(b2.add(j)), a2),
                            ),
                            vmulq_n_f32(vld1q_f32(b3.add(j)), a3),
                        );
                        vst1q_f32(op.add(j), vaddq_f32(vld1q_f32(op.add(j)), t));
                        j += 4;
                    }
                    while j < n {
                        *op.add(j) +=
                            a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
                        j += 1;
                    }
                    k += 4;
                }
                while k < k1 {
                    let av = arow[k];
                    let bp = b.as_ptr().add(k * n);
                    let mut j = 0;
                    while j + 4 <= n {
                        let t = vmulq_n_f32(vld1q_f32(bp.add(j)), av);
                        vst1q_f32(op.add(j), vaddq_f32(vld1q_f32(op.add(j)), t));
                        j += 4;
                    }
                    while j < n {
                        *op.add(j) += av * *bp.add(j);
                        j += 1;
                    }
                    k += 1;
                }
            }
        }
    }
}

/// Rows `i0..` of `A^T @ B` (bit-identical to `tensor::t_gemm_rows`).
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn t_gemm_rows(
    a: &[f32],
    ka: usize,
    b: &[f32],
    n: usize,
    m: usize,
    i0: usize,
    out_block: &mut [f32],
) {
    out_block.fill(0.0);
    if n == 0 {
        return;
    }
    let rows = out_block.len() / n;
    let mut r = 0;
    while r + 4 <= m {
        for i in 0..rows {
            let c = i0 + i;
            let (a0, a1) = (a[r * ka + c], a[(r + 1) * ka + c]);
            let (a2, a3) = (a[(r + 2) * ka + c], a[(r + 3) * ka + c]);
            let op = out_block.as_mut_ptr().add(i * n);
            let b0 = b.as_ptr().add(r * n);
            let b1 = b.as_ptr().add((r + 1) * n);
            let b2 = b.as_ptr().add((r + 2) * n);
            let b3 = b.as_ptr().add((r + 3) * n);
            let mut j = 0;
            while j + 4 <= n {
                let t = vaddq_f32(
                    vaddq_f32(
                        vaddq_f32(
                            vmulq_n_f32(vld1q_f32(b0.add(j)), a0),
                            vmulq_n_f32(vld1q_f32(b1.add(j)), a1),
                        ),
                        vmulq_n_f32(vld1q_f32(b2.add(j)), a2),
                    ),
                    vmulq_n_f32(vld1q_f32(b3.add(j)), a3),
                );
                vst1q_f32(op.add(j), vaddq_f32(vld1q_f32(op.add(j)), t));
                j += 4;
            }
            while j < n {
                *op.add(j) +=
                    a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
                j += 1;
            }
        }
        r += 4;
    }
    while r < m {
        for i in 0..rows {
            let av = a[r * ka + i0 + i];
            let op = out_block.as_mut_ptr().add(i * n);
            let bp = b.as_ptr().add(r * n);
            let mut j = 0;
            while j + 4 <= n {
                let t = vmulq_n_f32(vld1q_f32(bp.add(j)), av);
                vst1q_f32(op.add(j), vaddq_f32(vld1q_f32(op.add(j)), t));
                j += 4;
            }
            while j < n {
                *op.add(j) += av * *bp.add(j);
                j += 1;
            }
        }
        r += 1;
    }
}

/// Rows `r0..` of `A @ B^T` (bit-identical to `tensor::gemm_t_rows`): a
/// `float32x4` maps lane-for-lane onto the scalar kernel's 4 independent
/// accumulators; the horizontal sum extracts lanes in the scalar's
/// `((acc0 + acc1) + acc2) + acc3` order.
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_t_rows(
    a: &[f32],
    k_dim: usize,
    b: &[f32],
    b_rows: usize,
    r0: usize,
    out_block: &mut [f32],
) {
    if b_rows == 0 {
        return;
    }
    let rows = out_block.len() / b_rows;
    for i in 0..rows {
        let arow = &a[(r0 + i) * k_dim..(r0 + i) * k_dim + k_dim];
        let orow = &mut out_block[i * b_rows..(i + 1) * b_rows];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k_dim..(j + 1) * k_dim];
            let mut vacc = vdupq_n_f32(0.0);
            let chunks = k_dim / 4;
            for t in 0..chunks {
                let ca = vld1q_f32(arow.as_ptr().add(4 * t));
                let cb = vld1q_f32(brow.as_ptr().add(4 * t));
                vacc = vaddq_f32(vacc, vmulq_f32(ca, cb));
            }
            let mut acc = [0.0f32; 4];
            vst1q_f32(acc.as_mut_ptr(), vacc);
            let mut s = acc[0] + acc[1] + acc[2] + acc[3];
            for t in 4 * chunks..k_dim {
                s += arow[t] * brow[t];
            }
            *o = s;
        }
    }
}

/// i8×i8→i32 GEMM, unpacked row-major RHS (equal to
/// `quant::gemm_i8_i32_scalar`): 8 output columns per iteration via the
/// widening `vmull_n_s16`/`vmlal_n_s16` chain (exact integer arithmetic).
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_i8_i32(
    a: &[i8],
    k: usize,
    b: &[i8],
    n: usize,
    out: &mut [i32],
    kc: usize,
) {
    out.fill(0);
    if n == 0 || k == 0 {
        return;
    }
    let m = out.len() / n;
    for k0 in (0..k).step_by(kc) {
        let k1 = (k0 + kc).min(k);
        for i in 0..m {
            let arow = &a[i * k..i * k + k];
            let op = out.as_mut_ptr().add(i * n);
            let mut kk = k0;
            while kk + 4 <= k1 {
                let a0 = arow[kk] as i16;
                let a1 = arow[kk + 1] as i16;
                let a2 = arow[kk + 2] as i16;
                let a3 = arow[kk + 3] as i16;
                let b0 = b.as_ptr().add(kk * n);
                let b1 = b.as_ptr().add((kk + 1) * n);
                let b2 = b.as_ptr().add((kk + 2) * n);
                let b3 = b.as_ptr().add((kk + 3) * n);
                let mut j = 0;
                while j + 8 <= n {
                    let w0 = vmovl_s8(vld1_s8(b0.add(j)));
                    let w1 = vmovl_s8(vld1_s8(b1.add(j)));
                    let w2 = vmovl_s8(vld1_s8(b2.add(j)));
                    let w3 = vmovl_s8(vld1_s8(b3.add(j)));
                    let mut lo = vmull_n_s16(vget_low_s16(w0), a0);
                    lo = vmlal_n_s16(lo, vget_low_s16(w1), a1);
                    lo = vmlal_n_s16(lo, vget_low_s16(w2), a2);
                    lo = vmlal_n_s16(lo, vget_low_s16(w3), a3);
                    let mut hi = vmull_n_s16(vget_high_s16(w0), a0);
                    hi = vmlal_n_s16(hi, vget_high_s16(w1), a1);
                    hi = vmlal_n_s16(hi, vget_high_s16(w2), a2);
                    hi = vmlal_n_s16(hi, vget_high_s16(w3), a3);
                    vst1q_s32(op.add(j), vaddq_s32(vld1q_s32(op.add(j)), lo));
                    vst1q_s32(op.add(j + 4), vaddq_s32(vld1q_s32(op.add(j + 4)), hi));
                    j += 8;
                }
                while j < n {
                    *op.add(j) += a0 as i32 * *b0.add(j) as i32
                        + a1 as i32 * *b1.add(j) as i32
                        + a2 as i32 * *b2.add(j) as i32
                        + a3 as i32 * *b3.add(j) as i32;
                    j += 1;
                }
                kk += 4;
            }
            while kk < k1 {
                let av = arow[kk] as i16;
                let bp = b.as_ptr().add(kk * n);
                let mut j = 0;
                while j + 8 <= n {
                    let w = vmovl_s8(vld1_s8(bp.add(j)));
                    let lo = vmull_n_s16(vget_low_s16(w), av);
                    let hi = vmull_n_s16(vget_high_s16(w), av);
                    vst1q_s32(op.add(j), vaddq_s32(vld1q_s32(op.add(j)), lo));
                    vst1q_s32(op.add(j + 4), vaddq_s32(vld1q_s32(op.add(j + 4)), hi));
                    j += 8;
                }
                while j < n {
                    *op.add(j) += av as i32 * *bp.add(j) as i32;
                    j += 1;
                }
                kk += 1;
            }
        }
    }
}

/// i8×i8→i32 GEMM over the `PackedRhsI8` panel layout (equal to
/// `quant::gemm_i8_packed_i32_scalar`): `vld4_s8` de-interleaves the
/// stride-4 tap bytes of 8 columns in one load — the packed layout was
/// made for this instruction.
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_i8_packed_i32(
    a: &[i8],
    k: usize,
    packed: &[i8],
    n: usize,
    out: &mut [i32],
) {
    out.fill(0);
    if n == 0 || k == 0 {
        return;
    }
    let m = out.len() / n;
    let panels = k.div_ceil(4);
    for i in 0..m {
        let arow = &a[i * k..i * k + k];
        let op = out.as_mut_ptr().add(i * n);
        for p in 0..panels {
            let k0 = 4 * p;
            let a0 = arow[k0] as i16;
            let a1 = if k0 + 1 < k { arow[k0 + 1] as i16 } else { 0 };
            let a2 = if k0 + 2 < k { arow[k0 + 2] as i16 } else { 0 };
            let a3 = if k0 + 3 < k { arow[k0 + 3] as i16 } else { 0 };
            let panel = packed.as_ptr().add(p * 4 * n);
            let mut j = 0;
            while j + 8 <= n {
                let q = vld4_s8(panel.add(j * 4));
                let w0 = vmovl_s8(q.0);
                let w1 = vmovl_s8(q.1);
                let w2 = vmovl_s8(q.2);
                let w3 = vmovl_s8(q.3);
                let mut lo = vmull_n_s16(vget_low_s16(w0), a0);
                lo = vmlal_n_s16(lo, vget_low_s16(w1), a1);
                lo = vmlal_n_s16(lo, vget_low_s16(w2), a2);
                lo = vmlal_n_s16(lo, vget_low_s16(w3), a3);
                let mut hi = vmull_n_s16(vget_high_s16(w0), a0);
                hi = vmlal_n_s16(hi, vget_high_s16(w1), a1);
                hi = vmlal_n_s16(hi, vget_high_s16(w2), a2);
                hi = vmlal_n_s16(hi, vget_high_s16(w3), a3);
                vst1q_s32(op.add(j), vaddq_s32(vld1q_s32(op.add(j)), lo));
                vst1q_s32(op.add(j + 4), vaddq_s32(vld1q_s32(op.add(j + 4)), hi));
                j += 8;
            }
            while j < n {
                let q = panel.add(j * 4);
                *op.add(j) += a0 as i32 * *q as i32
                    + a1 as i32 * *q.add(1) as i32
                    + a2 as i32 * *q.add(2) as i32
                    + a3 as i32 * *q.add(3) as i32;
                j += 1;
            }
        }
    }
}

/// f32 depthwise conv at stride 1 (bit-identical to
/// `depthwise::conv_dw_f32_scalar`): taps move outside the 4-wide
/// output-x loop, preserving the ascending (ky, kx) per-element order.
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn conv_dw_f32(
    input: &[f32],
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    kernel: usize,
    weights: &[f32],
    out: &mut [f32],
) {
    assert_eq!(input.len(), channels * in_sp * in_sp, "input shape");
    assert_eq!(weights.len(), channels * kernel * kernel, "weight shape");
    assert_eq!(out.len(), channels * out_sp * out_sp, "output shape");
    let pad = kernel / 2;
    for c in 0..channels {
        let plane = &input[c * in_sp * in_sp..(c + 1) * in_sp * in_sp];
        let w = &weights[c * kernel * kernel..(c + 1) * kernel * kernel];
        let oplane = &mut out[c * out_sp * out_sp..(c + 1) * out_sp * out_sp];
        for oy in 0..out_sp {
            let orow = &mut oplane[oy * out_sp..(oy + 1) * out_sp];
            orow.fill(0.0);
            let op = orow.as_mut_ptr();
            for ky in 0..kernel {
                let iy = (oy + ky) as isize - pad as isize;
                if iy < 0 || iy >= in_sp as isize {
                    continue;
                }
                let row = plane.as_ptr().add(iy as usize * in_sp);
                let wrow = &w[ky * kernel..(ky + 1) * kernel];
                for (kx, &wv) in wrow.iter().enumerate() {
                    let lo = pad.saturating_sub(kx);
                    let hi = (in_sp + pad).saturating_sub(kx).min(out_sp);
                    if lo >= hi {
                        continue;
                    }
                    let src = row.add(lo + kx - pad);
                    let mut j = lo;
                    while j + 4 <= hi {
                        let t = vmulq_n_f32(vld1q_f32(src.add(j - lo)), wv);
                        vst1q_f32(op.add(j), vaddq_f32(vld1q_f32(op.add(j)), t));
                        j += 4;
                    }
                    while j < hi {
                        *op.add(j) += *src.add(j - lo) * wv;
                        j += 1;
                    }
                }
            }
        }
    }
}

/// i8 depthwise conv at stride 1 (equal to
/// `depthwise::conv_dw_i8_scalar`): interior groups of 8 output columns
/// accumulate the window in two `int32x4` registers; border groups run the
/// scalar per-element path.
///
/// # Safety
/// Requires NEON (runtime-detected by the dispatcher).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn conv_dw_i8(
    input: &[i8],
    a_scale: f32,
    channels: usize,
    in_sp: usize,
    out_sp: usize,
    w: &QuantizedDwWeights,
    out: &mut [f32],
) {
    assert_eq!(w.channels, channels, "filter bank channels");
    assert_eq!(input.len(), channels * in_sp * in_sp, "input shape");
    assert_eq!(out.len(), channels * out_sp * out_sp, "output shape");
    let kernel = w.kernel;
    let pad = kernel / 2;
    // ox range where all 8 lanes' taps stay inside the input row
    let int_lo = pad;
    let int_hi = (in_sp + pad).saturating_sub(kernel + 6);
    for c in 0..channels {
        let plane = &input[c * in_sp * in_sp..(c + 1) * in_sp * in_sp];
        let taps = &w.data[c * kernel * kernel..(c + 1) * kernel * kernel];
        let scale = a_scale * w.scales[c];
        let oplane = &mut out[c * out_sp * out_sp..(c + 1) * out_sp * out_sp];
        for oy in 0..out_sp {
            let orow = &mut oplane[oy * out_sp..(oy + 1) * out_sp];
            let mut ox = 0;
            while ox < out_sp {
                if ox >= int_lo && ox < int_hi && ox + 8 <= out_sp {
                    let mut acc_lo = vdupq_n_s32(0);
                    let mut acc_hi = vdupq_n_s32(0);
                    for ky in 0..kernel {
                        let iy = (oy + ky) as isize - pad as isize;
                        if iy < 0 || iy >= in_sp as isize {
                            continue;
                        }
                        let row = plane.as_ptr().add(iy as usize * in_sp);
                        for kx in 0..kernel {
                            let coeff = taps[ky * kernel + kx] as i16;
                            let v = vmovl_s8(vld1_s8(row.add(ox + kx - pad)));
                            acc_lo = vmlal_n_s16(acc_lo, vget_low_s16(v), coeff);
                            acc_hi = vmlal_n_s16(acc_hi, vget_high_s16(v), coeff);
                        }
                    }
                    let mut acc = [0i32; 8];
                    vst1q_s32(acc.as_mut_ptr(), acc_lo);
                    vst1q_s32(acc.as_mut_ptr().add(4), acc_hi);
                    for (l, &q) in acc.iter().enumerate() {
                        orow[ox + l] = q as f32 * scale;
                    }
                    ox += 8;
                } else {
                    let mut acc = 0i32;
                    for ky in 0..kernel {
                        let iy = (oy + ky) as isize - pad as isize;
                        if iy < 0 || iy >= in_sp as isize {
                            continue;
                        }
                        let row = &plane[iy as usize * in_sp..(iy as usize + 1) * in_sp];
                        let wrow = &taps[ky * kernel..(ky + 1) * kernel];
                        for (kx, &tv) in wrow.iter().enumerate() {
                            let ix = (ox + kx) as isize - pad as isize;
                            if ix < 0 || ix >= in_sp as isize {
                                continue;
                            }
                            acc += row[ix as usize] as i32 * tv as i32;
                        }
                    }
                    orow[ox] = acc as f32 * scale;
                    ox += 1;
                }
            }
        }
    }
}
