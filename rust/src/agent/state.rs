//! Agent state construction (paper: "a layer-specific state is constructed
//! and passed to a reinforcement agent").
//!
//! Features per time step t (layer): static layer descriptors, dynamic
//! MAC-budget accounting under the partial policy P_{e,t}, capability flags,
//! the previous action a_{t-1}, and the layer's sensitivity profile
//! (Eq. 5 probes) — the paper's central addition over AMC/HAQ.

use crate::compress::DiscretePolicy;
use crate::eval::SensitivityTable;
use crate::hw::mix_supported;
use crate::model::{LayerKind, ModelIr};

/// Assembles the per-layer-step state vectors the agents consume.
pub struct StateBuilder {
    max_channels: f32,
    total_macs: f64,
    img: f32,
    action_dim: usize,
    sens_dim: usize,
}

impl StateBuilder {
    /// A builder for `ir`'s layers with `sens`'s sensitivity features.
    pub fn new(ir: &ModelIr, sens: &SensitivityTable, action_dim: usize) -> Self {
        Self {
            max_channels: ir.layers.iter().map(|l| l.cout).max().unwrap_or(1) as f32,
            total_macs: ir.total_macs() as f64,
            img: ir.img as f32,
            action_dim,
            sens_dim: sens.feature_dim(),
        }
    }

    /// Dimension of the state vectors this builder emits.
    pub fn dim(&self) -> usize {
        14 + self.action_dim + self.sens_dim
    }

    /// Build s_t for layer `idx` given the policy decided so far and the
    /// previous action.
    pub fn build(
        &self,
        ir: &ModelIr,
        sens: &SensitivityTable,
        policy: &DiscretePolicy,
        idx: usize,
        step: usize,
        num_steps: usize,
        prev_action: &[f32],
    ) -> Vec<f32> {
        let l = &ir.layers[idx];
        let mut s = Vec::with_capacity(self.dim());
        s.push(step as f32 / num_steps.max(1) as f32);
        s.push((l.kind == LayerKind::Conv) as u8 as f32);
        s.push((l.kind == LayerKind::Linear) as u8 as f32);
        s.push(l.cin as f32 / self.max_channels);
        s.push(l.cout as f32 / self.max_channels);
        s.push(l.kernel as f32 / 3.0);
        s.push(l.stride as f32 / 2.0);
        s.push(l.out_spatial as f32 / self.img);
        s.push(((l.macs() as f64 + 1.0).ln() / (self.total_macs + 1.0).ln()) as f32);

        // MAC budget accounting under the partial policy: spent on layers
        // before `idx` (already decided), original cost for the rest.
        let mut done = 0u64;
        let mut rest = 0u64;
        for m in &ir.layers {
            if m.index < idx {
                let cin = policy.effective_cin(ir, m.index);
                done += m.macs_at(cin, policy.layers[m.index].kept_channels);
            } else {
                rest += m.macs();
            }
        }
        s.push((done as f64 / self.total_macs) as f32);
        s.push((rest as f64 / self.total_macs) as f32);

        s.push(l.prunable as u8 as f32);
        s.push(mix_supported(l, l.cin, l.cout) as u8 as f32);
        // depthwise flag: the agent must be able to tell channel-coupled
        // depthwise layers (no MIX, width follows the producer) from dense
        // convs of the same shape
        s.push(l.depthwise as u8 as f32);

        debug_assert_eq!(prev_action.len(), self.action_dim);
        s.extend_from_slice(prev_action);
        s.extend(sens.layer_features(idx));
        debug_assert_eq!(s.len(), self.dim());
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::SensitivityConfig;
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn setup() -> (ModelIr, SensitivityTable) {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sens =
            SensitivityTable::disabled(ir.layers.len(), &SensitivityConfig::default(), "tiny");
        (ir, sens)
    }

    #[test]
    fn state_dim_consistent() {
        let (ir, sens) = setup();
        let sb = StateBuilder::new(&ir, &sens, 3);
        let p = DiscretePolicy::reference(&ir);
        let s = sb.build(&ir, &sens, &p, 0, 0, ir.layers.len(), &[0.0; 3]);
        assert_eq!(s.len(), sb.dim());
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn budget_features_move_with_progress() {
        let (ir, sens) = setup();
        let sb = StateBuilder::new(&ir, &sens, 1);
        let p = DiscretePolicy::reference(&ir);
        let n = ir.layers.len();
        let s0 = sb.build(&ir, &sens, &p, 0, 0, n, &[0.0]);
        let s_last = sb.build(&ir, &sens, &p, n - 1, n - 1, n, &[0.0]);
        // done fraction grows, rest fraction shrinks
        assert!(s_last[9] > s0[9]);
        assert!(s_last[10] < s0[10]);
        // step fraction
        assert_eq!(s0[0], 0.0);
        assert!((s_last[0] - (n - 1) as f32 / n as f32).abs() < 1e-6);
    }

    #[test]
    fn pruning_reflected_in_done_macs() {
        let (ir, sens) = setup();
        let sb = StateBuilder::new(&ir, &sens, 1);
        let mut p = DiscretePolicy::reference(&ir);
        let full = sb.build(&ir, &sens, &p, 3, 3, ir.layers.len(), &[0.0]);
        p.layers[1].kept_channels = 2;
        let pruned = sb.build(&ir, &sens, &p, 3, 3, ir.layers.len(), &[0.0]);
        assert!(pruned[9] < full[9]);
    }

    #[test]
    fn capability_flags() {
        let (ir, sens) = setup();
        let sb = StateBuilder::new(&ir, &sens, 1);
        let p = DiscretePolicy::reference(&ir);
        let n = ir.layers.len();
        let stem = sb.build(&ir, &sens, &p, 0, 0, n, &[0.0]);
        assert_eq!(stem[11], 0.0, "stem not prunable");
        let conv1 = sb.build(&ir, &sens, &p, 1, 1, n, &[0.0]);
        assert_eq!(conv1[11], 1.0);
        // tiny model: cin=8 < 32 => MIX unsupported everywhere
        assert_eq!(stem[12], 0.0);
        // tiny model has no depthwise layers
        assert_eq!(stem[13], 0.0);
        assert_eq!(conv1[13], 0.0);
    }

    #[test]
    fn depthwise_flag_feature() {
        let ir = ModelIr::from_meta(&crate::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
        let sens = SensitivityTable::disabled(
            ir.layers.len(),
            &SensitivityConfig::default(),
            "mobilenetv2s",
        );
        let sb = StateBuilder::new(&ir, &sens, 3);
        let p = DiscretePolicy::reference(&ir);
        let n = ir.layers.len();
        for l in &ir.layers {
            let s = sb.build(&ir, &sens, &p, l.index, l.index, n, &[0.0; 3]);
            assert_eq!(s.len(), sb.dim());
            assert_eq!(s[13], l.depthwise as u8 as f32, "{}", l.name);
        }
    }
}
