//! Experience replay buffer (paper: capacity 2000 transitions; the episode
//! count it holds varies with the per-episode step count).

use std::collections::VecDeque;

use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
/// One (s, a, r, s') experience tuple.
pub struct Transition {
    /// State the action was taken in.
    pub state: Vec<f32>,
    /// The action taken.
    pub action: Vec<f32>,
    /// Per-episode shared reward (assigned to every step of the episode).
    pub reward: f32,
    /// Successor state (zeroed when terminal).
    pub next_state: Vec<f32>,
    /// Last step of the episode (no bootstrap through the terminal).
    pub terminal: bool,
}

#[derive(Clone, Debug)]
/// Fixed-capacity ring buffer of transitions with uniform sampling.
pub struct ReplayBuffer {
    cap: usize,
    items: VecDeque<Transition>,
}

impl ReplayBuffer {
    /// An empty buffer holding at most `cap` transitions.
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            items: VecDeque::with_capacity(cap),
        }
    }

    /// Append a transition, evicting the oldest at capacity.
    pub fn push(&mut self, t: Transition) {
        if self.items.len() == self.cap {
            self.items.pop_front();
        }
        self.items.push_back(t);
    }

    /// Number of stored transitions.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Whether the buffer holds no transitions.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Maximum number of stored transitions.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Uniform sample with replacement-free indices (batch <= len).
    pub fn sample<'a>(&'a self, batch: usize, rng: &mut Pcg64) -> Vec<&'a Transition> {
        let n = self.items.len();
        let k = batch.min(n);
        rng.sample_indices(n, k)
            .into_iter()
            .map(|i| &self.items[i])
            .collect()
    }

    /// `sample` without the per-call Vec: fills `idx` with distinct indices
    /// into the buffer (resolve them with `get`).  Draws from `rng` exactly
    /// like `sample`, so the two paths are trajectory-identical.
    pub fn sample_into(&self, batch: usize, rng: &mut Pcg64, idx: &mut Vec<usize>) {
        let n = self.items.len();
        rng.sample_indices_into(n, batch.min(n), idx);
    }

    /// Transition at index `i` (for `sample_into` consumers).
    pub fn get(&self, i: usize) -> &Transition {
        &self.items[i]
    }

    /// Serialize capacity + every stored transition in buffer order
    /// (checkpoint format); round-trips bit-exactly through
    /// [`ReplayBuffer::from_json`], so sampling after a resume sees the
    /// identical buffer the uninterrupted run would.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("cap", Json::num(self.cap as f64)),
            (
                "items",
                Json::Arr(
                    self.items
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("s", Json::arr_f32(&t.state)),
                                ("a", Json::arr_f32(&t.action)),
                                ("r", Json::num(t.reward as f64)),
                                ("ns", Json::arr_f32(&t.next_state)),
                                ("t", Json::Bool(t.terminal)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild a buffer serialized by [`ReplayBuffer::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let cap = j.req_usize("cap")?;
        anyhow::ensure!(cap > 0, "replay capacity must be positive");
        let mut buf = Self::new(cap);
        for e in j.req_arr("items")? {
            buf.push(Transition {
                state: e.req_f32s("s")?,
                action: e.req_f32s("a")?,
                reward: e.req_f64("r")? as f32,
                next_state: e.req_f32s("ns")?,
                terminal: e.req_bool("t")?,
            });
        }
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f32) -> Transition {
        Transition {
            state: vec![r],
            action: vec![0.5],
            reward: r,
            next_state: vec![r + 1.0],
            terminal: false,
        }
    }

    #[test]
    fn bounded_capacity_fifo() {
        let mut buf = ReplayBuffer::new(3);
        for i in 0..5 {
            buf.push(t(i as f32));
        }
        assert_eq!(buf.len(), 3);
        // oldest (0, 1) evicted
        let rewards: Vec<f32> = buf.items.iter().map(|t| t.reward).collect();
        assert_eq!(rewards, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn sample_distinct_and_bounded() {
        let mut buf = ReplayBuffer::new(100);
        for i in 0..50 {
            buf.push(t(i as f32));
        }
        let mut rng = Pcg64::new(1);
        let s = buf.sample(20, &mut rng);
        assert_eq!(s.len(), 20);
        let s = buf.sample(200, &mut rng);
        assert_eq!(s.len(), 50, "clamped to buffer size");
    }

    #[test]
    fn json_roundtrip_preserves_order_and_bits() {
        use crate::util::json::Json;
        let mut buf = ReplayBuffer::new(8);
        for i in 0..12 {
            // overflow the capacity so eviction order is exercised too
            buf.push(t(i as f32 * 0.3 - 1.7));
        }
        let back = ReplayBuffer::from_json(&Json::parse(&buf.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.capacity(), buf.capacity());
        assert_eq!(back.len(), buf.len());
        for i in 0..buf.len() {
            assert_eq!(back.get(i), buf.get(i));
        }
    }

    #[test]
    fn sample_into_matches_sample() {
        let mut buf = ReplayBuffer::new(100);
        for i in 0..50 {
            buf.push(t(i as f32));
        }
        let mut r1 = Pcg64::new(7);
        let mut r2 = Pcg64::new(7);
        let mut idx = Vec::new();
        for _ in 0..20 {
            let by_ref = buf.sample(16, &mut r1);
            buf.sample_into(16, &mut r2, &mut idx);
            assert_eq!(idx.len(), by_ref.len());
            for (a, &i) in by_ref.iter().zip(&idx) {
                assert_eq!(*a, buf.get(i));
            }
        }
    }
}
