//! The three reinforcement-learning agents (paper §Proposed Agents).
//!
//! All share one DDPG core (actor 400/300 + Sigmoid, critic 400/300,
//! Adam 1e-4/1e-3, gamma 0.99, replay 2000, batch 128, truncated-normal
//! exploration noise sigma0=0.5 decaying 0.95/episode, running state
//! standardization, moving-average reward normalization) and differ in the
//! action space and the action -> policy mapping:
//!
//! * pruning agent      — 1 action/layer: channel compression ratio;
//! * quantization agent — 2 actions/layer: activation + weight actions
//!   mapped through the t_mix/t_int8 thresholds (Eq. 8);
//! * joint agent        — 3 actions/layer: pruning (rounded to multiples of
//!   32 for bit-serial compatibility) + both quantization actions.

mod ddpg;
mod mapper;
mod replay;
mod state;

pub use ddpg::{Ddpg, DdpgConfig};
pub use mapper::{
    mapper_for, AgentKind, JointMapper, PolicyMapper, PruningMapper, QuantizationMapper,
};
pub use replay::{ReplayBuffer, Transition};
pub use state::StateBuilder;
