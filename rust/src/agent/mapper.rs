//! Action -> policy mapping for the three agents.
//!
//! A mapper decides (a) which layers constitute the episode's time steps and
//! (b) how a continuous action vector updates the `DiscretePolicy` at one
//! layer, enforcing hardware constraints (channel rounding, MIX support
//! fallback) exactly as the deployed runtime would.

use crate::compress::{discretize, select_quant_mode, DiscretePolicy, DiscretizeOpts};
#[cfg(test)]
use crate::compress::QuantMode;
use crate::hw::mix_supported;
use crate::model::ModelIr;

/// The three agent kinds of the paper (one per compression method plus
/// the joint agent).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgentKind {
    /// Channel-pruning-only agent (1 action per layer).
    Pruning,
    /// Quantization-only agent (2 actions per layer).
    Quantization,
    /// Joint pruning + quantization agent (3 actions per layer).
    Joint,
}

/// Parses the CLI labels `pruning`/`quantization`/`joint` (with the short
/// aliases `prune`/`quant`) — the inverse of the `Display` labels.
impl std::str::FromStr for AgentKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pruning" | "prune" => Ok(Self::Pruning),
            "quantization" | "quant" => Ok(Self::Quantization),
            "joint" => Ok(Self::Joint),
            other => anyhow::bail!("unknown agent kind '{other}' (pruning|quantization|joint)"),
        }
    }
}

/// Stable lowercase label (CLI, records, artifacts); honors format padding.
impl std::fmt::Display for AgentKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            Self::Pruning => "pruning",
            Self::Quantization => "quantization",
            Self::Joint => "joint",
        })
    }
}

/// Action -> policy mapping strategy of one agent kind.
pub trait PolicyMapper: Send + Sync {
    /// Which agent kind this mapper implements.
    fn kind(&self) -> AgentKind;
    /// Length of the action vectors the mapper consumes.
    fn action_dim(&self) -> usize;
    /// Layer indices that get a time step, in forward order.
    fn steps(&self, ir: &ModelIr) -> Vec<usize>;
    /// Apply `action` to `policy` at layer `idx`.
    fn apply(&self, ir: &ModelIr, policy: &mut DiscretePolicy, idx: usize, action: &[f32]);
}

/// Pruning agent: one action = channel compression ratio r (Eq. 4).
#[derive(Clone, Debug)]
pub struct PruningMapper {
    /// Channel rounding/minimum rules for discretization.
    pub opts: DiscretizeOpts,
    /// Cap on the pruning ratio (keeps >= (1-max)·cout channels).
    pub max_ratio: f64,
}

impl Default for PruningMapper {
    fn default() -> Self {
        Self {
            opts: DiscretizeOpts::default(),
            max_ratio: 0.9,
        }
    }
}

impl PruningMapper {
    /// The channel-rounded variant used in sequential/joint comparisons
    /// (paper appendix: "we applied the same channel rounding restriction
    /// as for the joint agent").
    pub fn rounded() -> Self {
        Self {
            opts: DiscretizeOpts {
                channel_multiple: 32,
                min_channels: 1,
            },
            max_ratio: 0.9,
        }
    }
}

impl PolicyMapper for PruningMapper {
    fn kind(&self) -> AgentKind {
        AgentKind::Pruning
    }
    fn action_dim(&self) -> usize {
        1
    }
    fn steps(&self, ir: &ModelIr) -> Vec<usize> {
        ir.prunable_layers()
    }
    fn apply(&self, ir: &ModelIr, policy: &mut DiscretePolicy, idx: usize, action: &[f32]) {
        let l = &ir.layers[idx];
        if !l.prunable {
            return; // dependency-coupled layers never accept pruning actions
        }
        let r = (action[0] as f64).clamp(0.0, 1.0) * self.max_ratio;
        let kept = discretize(r, l.cout, self.opts);
        policy.layers[idx].kept_channels = kept;
        // Depthwise consumers are channel-coupled to their producer: a
        // depthwise conv has one filter per input channel, so pruning the
        // expand layer removes the matching depthwise filters.  Keep the
        // coupled width in lockstep (the MobileNet analogue of the
        // residual-group restriction — the agent never acts on the
        // depthwise layer directly).
        for &j in &ir.consumers[idx] {
            let d = &ir.layers[j];
            if d.depthwise {
                policy.layers[j].kept_channels = kept.min(d.cout);
            }
        }
    }
}

/// Quantization agent: two actions (activation, weight) through the
/// t_mix/t_int8 thresholds.
#[derive(Clone, Debug)]
pub struct QuantizationMapper {
    /// MIX exploration-range cap (paper: 6 bits).
    pub max_bits: u8,
}

impl Default for QuantizationMapper {
    fn default() -> Self {
        Self { max_bits: 6 }
    }
}

impl PolicyMapper for QuantizationMapper {
    fn kind(&self) -> AgentKind {
        AgentKind::Quantization
    }
    fn action_dim(&self) -> usize {
        2
    }
    fn steps(&self, ir: &ModelIr) -> Vec<usize> {
        (0..ir.layers.len()).collect()
    }
    fn apply(&self, ir: &ModelIr, policy: &mut DiscretePolicy, idx: usize, action: &[f32]) {
        let l = &ir.layers[idx];
        let eff_cin = policy.effective_cin(ir, idx);
        let eff_cout = policy.layers[idx].kept_channels;
        let supported = mix_supported(l, eff_cin, eff_cout);
        policy.layers[idx].quant = select_quant_mode(
            (action[0] as f64).clamp(0.0, 1.0),
            (action[1] as f64).clamp(0.0, 1.0),
            supported,
            self.max_bits,
        );
    }
}

/// Joint agent: [pruning ratio, activation action, weight action]; pruning
/// rounds to multiples of 32 so consumers stay bit-serial-compatible.
#[derive(Clone, Debug)]
pub struct JointMapper {
    /// The pruning half (channel-rounded, see `PruningMapper::rounded`).
    pub prune: PruningMapper,
    /// The quantization half.
    pub quant: QuantizationMapper,
}

impl Default for JointMapper {
    fn default() -> Self {
        Self {
            prune: PruningMapper::rounded(),
            quant: QuantizationMapper::default(),
        }
    }
}

impl PolicyMapper for JointMapper {
    fn kind(&self) -> AgentKind {
        AgentKind::Joint
    }
    fn action_dim(&self) -> usize {
        3
    }
    fn steps(&self, ir: &ModelIr) -> Vec<usize> {
        (0..ir.layers.len()).collect()
    }
    fn apply(&self, ir: &ModelIr, policy: &mut DiscretePolicy, idx: usize, action: &[f32]) {
        // pruning first: the rounded channel count decides MIX support
        self.prune.apply(ir, policy, idx, &action[..1]);
        self.quant.apply(ir, policy, idx, &action[1..]);
    }
}

/// Construct the default mapper for an agent kind.
pub fn mapper_for(kind: AgentKind) -> Box<dyn PolicyMapper> {
    match kind {
        AgentKind::Pruning => Box::new(PruningMapper::default()),
        AgentKind::Quantization => Box::new(QuantizationMapper::default()),
        AgentKind::Joint => Box::new(JointMapper::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn ir() -> ModelIr {
        ModelIr::from_meta(&tiny_meta()).unwrap()
    }

    #[test]
    fn pruning_mapper_steps_only_prunable() {
        let ir = ir();
        let m = PruningMapper::default();
        assert_eq!(m.steps(&ir), vec![1, 3]);
    }

    #[test]
    fn pruning_action_monotone() {
        let ir = ir();
        let m = PruningMapper::default();
        let mut kept = Vec::new();
        for a in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
            let mut p = DiscretePolicy::reference(&ir);
            m.apply(&ir, &mut p, 1, &[a]);
            kept.push(p.layers[1].kept_channels);
        }
        assert_eq!(kept[0], ir.layers[1].cout);
        for w in kept.windows(2) {
            assert!(w[1] <= w[0], "{kept:?}");
        }
        assert!(*kept.last().unwrap() >= 1);
    }

    #[test]
    fn pruning_refuses_dependent_layers() {
        let ir = ir();
        let m = PruningMapper::default();
        let mut p = DiscretePolicy::reference(&ir);
        m.apply(&ir, &mut p, 0, &[1.0]); // stem is group 0
        assert_eq!(p.layers[0].kept_channels, ir.layers[0].cout);
    }

    #[test]
    fn quant_mapper_thresholds_and_fallback() {
        let ir = ir();
        let m = QuantizationMapper::default();
        let mut p = DiscretePolicy::reference(&ir);
        m.apply(&ir, &mut p, 1, &[0.1, 0.1]);
        assert_eq!(p.layers[1].quant, QuantMode::Fp32);
        m.apply(&ir, &mut p, 1, &[0.3, 0.1]);
        assert_eq!(p.layers[1].quant, QuantMode::Int8);
        // tiny model never supports MIX (cin < 32) => INT8 fallback
        m.apply(&ir, &mut p, 1, &[0.9, 0.9]);
        assert_eq!(p.layers[1].quant, QuantMode::Int8);
    }

    #[test]
    fn joint_mapper_combines() {
        let ir = ir();
        let m = JointMapper::default();
        assert_eq!(m.action_dim(), 3);
        let mut p = DiscretePolicy::reference(&ir);
        m.apply(&ir, &mut p, 1, &[0.8, 0.3, 0.1]);
        // channel rounding to 32 on an 8-wide layer keeps all 8
        assert_eq!(p.layers[1].kept_channels, 8);
        assert_eq!(p.layers[1].quant, QuantMode::Int8);
    }

    #[test]
    fn joint_rounding_on_wide_layer() {
        // fabricate a wide prunable layer to exercise the 32-rounding
        let mut meta = tiny_meta();
        meta.layers[1].cout = 128;
        meta.layers[2].cin = 128;
        for p in &mut meta.params {
            if p.name == "s0b0.conv1.w" {
                p.shape = vec![3, 3, 8, 128];
            }
            if p.name == "s0b0.conv2.w" {
                p.shape = vec![3, 3, 128, 8];
            }
            if p.name.starts_with("s0b0.conv1.bn") {
                p.shape = vec![128];
            }
        }
        let ir = ModelIr::from_meta(&meta).unwrap();
        let m = JointMapper::default();
        let mut p = DiscretePolicy::reference(&ir);
        m.apply(&ir, &mut p, 1, &[0.6, 0.0, 0.0]);
        let kept = p.layers[1].kept_channels;
        assert_eq!(kept % 32, 0, "kept={kept}");
        assert!(kept < 128 && kept >= 32);
    }

    #[test]
    fn pruning_expand_propagates_to_depthwise_consumer() {
        let ir = ModelIr::from_meta(&crate::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
        let m = PruningMapper::default();
        let expand = ir.layer_by_name("s1b1.expand").unwrap().index;
        let dw = ir.layer_by_name("s1b1.dw").unwrap().index;
        let mut p = DiscretePolicy::reference(&ir);
        m.apply(&ir, &mut p, expand, &[0.5]);
        let kept = p.layers[expand].kept_channels;
        assert!(kept < ir.layers[expand].cout, "action 0.5 must prune");
        assert_eq!(
            p.layers[dw].kept_channels, kept,
            "depthwise width must follow its expand producer"
        );
        // the project layer reads the depthwise width downstream
        let project = ir.layer_by_name("s1b1.project").unwrap().index;
        assert_eq!(p.effective_cin(&ir, project), kept);
        // the depthwise layer itself refuses direct pruning actions
        m.apply(&ir, &mut p, dw, &[1.0]);
        assert_eq!(p.layers[dw].kept_channels, kept);
    }

    #[test]
    fn quant_mapper_masks_mix_on_depthwise() {
        let ir = ModelIr::from_meta(&crate::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
        let m = QuantizationMapper::default();
        let mut p = DiscretePolicy::reference(&ir);
        for l in ir.layers.iter().filter(|l| l.depthwise) {
            // strongest possible MIX request: still INT8 on depthwise
            m.apply(&ir, &mut p, l.index, &[0.95, 0.95]);
            assert_eq!(p.layers[l.index].quant, QuantMode::Int8, "{}", l.name);
        }
        // sanity: a dense layer satisfying the constraints does go MIX
        let dense = ir.layer_by_name("s2b1.project").unwrap();
        assert!(dense.cin % 32 == 0 && dense.cout % 8 == 0);
        m.apply(&ir, &mut p, dense.index, &[0.95, 0.95]);
        assert!(p.layers[dense.index].quant.is_mix());
    }

    #[test]
    fn joint_mapper_keeps_depthwise_coupling() {
        let ir = ModelIr::from_meta(&crate::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
        let m = JointMapper::default();
        let mut p = DiscretePolicy::reference(&ir);
        // walk a whole episode's steps like the driver does
        for (k, idx) in m.steps(&ir).iter().copied().enumerate() {
            let a = [0.6 + 0.01 * (k % 5) as f32, 0.4, 0.4];
            m.apply(&ir, &mut p, idx, &a);
        }
        for l in ir.layers.iter().filter(|l| l.depthwise) {
            let producer = ir
                .producer_of(l.index)
                .expect("every depthwise conv has a producer");
            assert_eq!(
                p.layers[l.index].kept_channels, p.layers[producer].kept_channels,
                "{} decoupled from its producer",
                l.name
            );
            assert!(!p.layers[l.index].quant.is_mix(), "{}", l.name);
        }
    }

    #[test]
    fn agent_kind_parse_display_roundtrip() {
        assert_eq!("joint".parse::<AgentKind>().unwrap(), AgentKind::Joint);
        assert_eq!("prune".parse::<AgentKind>().unwrap(), AgentKind::Pruning);
        assert!("nope".parse::<AgentKind>().is_err());
        for kind in [AgentKind::Pruning, AgentKind::Quantization, AgentKind::Joint] {
            assert_eq!(kind.to_string().parse::<AgentKind>().unwrap(), kind);
        }
        // Display honors width specifiers (the report tables rely on it)
        assert_eq!(format!("{:9}", AgentKind::Joint), "joint    ");
    }
}
