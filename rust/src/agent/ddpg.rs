//! DDPG core (Lillicrap et al.) with the paper's hyperparameters.

use crate::nn::{Activation, Adam, Mlp, TrainWorkspace};
use crate::tensor::Mat;
use crate::util::rng::Pcg64;
use crate::util::stats::{Ema, RunningNorm};

use super::replay::{ReplayBuffer, Transition};

/// Pre-sized scratch for `optimize`.  Every buffer is reused across steps,
/// so the steady-state optimization step performs no heap allocation (the
/// first step at a given batch shape sizes everything).
#[derive(Default)]
struct OptimizeWorkspace {
    /// Sampled replay indices.
    idx: Vec<usize>,
    rewards: Vec<f32>,
    terminals: Vec<bool>,
    states: Mat,
    actions: Mat,
    next_states: Mat,
    /// [state | action] critic inputs.
    sa: Mat,
    next_sa: Mat,
    sa_mu: Mat,
    /// TD targets.
    y: Mat,
    dout: Mat,
    dq: Mat,
    /// dQ/daction slice for the actor update.
    da: Mat,
    actor_ws: TrainWorkspace,
    critic_ws: TrainWorkspace,
    actor_tgt_ws: TrainWorkspace,
    critic_tgt_ws: TrainWorkspace,
}

/// DDPG hyper-parameters (paper defaults in `Default`).
#[derive(Clone, Debug)]
pub struct DdpgConfig {
    /// Hidden widths of both networks (paper: 400/300).
    pub hidden: (usize, usize),
    /// Actor Adam learning rate.
    pub actor_lr: f32,
    /// Critic Adam learning rate.
    pub critic_lr: f32,
    /// Discount factor.
    pub gamma: f32,
    /// Polyak factor for the target networks.
    pub tau: f32,
    /// Optimization batch size.
    pub batch: usize,
    /// Replay buffer capacity (transitions).
    pub replay_capacity: usize,
    /// Initial exploration noise sigma (Eq. 7).
    pub sigma0: f64,
    /// Per-episode multiplicative decay of sigma.
    pub sigma_decay: f64,
    /// Moving-average constant for reward normalization.
    pub reward_ema: f64,
    /// Gradient clip (global L2) for both networks.
    pub grad_clip: f32,
}

impl Default for DdpgConfig {
    fn default() -> Self {
        Self {
            hidden: (400, 300),
            actor_lr: 1e-4,
            critic_lr: 1e-3,
            gamma: 0.99,
            tau: 0.01,
            batch: 128,
            replay_capacity: 2000,
            sigma0: 0.5,
            sigma_decay: 0.95,
            reward_ema: 0.05,
            grad_clip: 5.0,
        }
    }
}

impl DdpgConfig {
    /// Serialize every hyper-parameter (checkpoint format).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("hidden", Json::arr_usize(&[self.hidden.0, self.hidden.1])),
            ("actor_lr", Json::num(self.actor_lr as f64)),
            ("critic_lr", Json::num(self.critic_lr as f64)),
            ("gamma", Json::num(self.gamma as f64)),
            ("tau", Json::num(self.tau as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("replay_capacity", Json::num(self.replay_capacity as f64)),
            ("sigma0", Json::num(self.sigma0)),
            ("sigma_decay", Json::num(self.sigma_decay)),
            ("reward_ema", Json::num(self.reward_ema)),
            ("grad_clip", Json::num(self.grad_clip as f64)),
        ])
    }

    /// Rebuild a configuration serialized by [`DdpgConfig::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let hidden = j.req_f64s("hidden")?;
        anyhow::ensure!(hidden.len() == 2, "ddpg 'hidden' must be [h1, h2]");
        Ok(Self {
            hidden: (hidden[0] as usize, hidden[1] as usize),
            actor_lr: j.req_f64("actor_lr")? as f32,
            critic_lr: j.req_f64("critic_lr")? as f32,
            gamma: j.req_f64("gamma")? as f32,
            tau: j.req_f64("tau")? as f32,
            batch: j.req_usize("batch")?,
            replay_capacity: j.req_usize("replay_capacity")?,
            sigma0: j.req_f64("sigma0")?,
            sigma_decay: j.req_f64("sigma_decay")?,
            reward_ema: j.req_f64("reward_ema")?,
            grad_clip: j.req_f64("grad_clip")? as f32,
        })
    }
}

/// Actor-critic pair with targets, replay, normalizers and exploration state.
pub struct Ddpg {
    /// The hyper-parameters the agent was built with.
    pub cfg: DdpgConfig,
    /// The policy network.
    pub actor: Mlp,
    /// The value network.
    pub critic: Mlp,
    actor_target: Mlp,
    critic_target: Mlp,
    actor_opt: Adam,
    critic_opt: Adam,
    /// Experience replay buffer.
    pub replay: ReplayBuffer,
    state_norm: RunningNorm,
    reward_mean: Ema,
    reward_scale: Ema,
    /// Current exploration noise sigma (decayed per episode).
    pub sigma: f64,
    rng: Pcg64,
    state_dim: usize,
    action_dim: usize,
    ws: OptimizeWorkspace,
}

impl Ddpg {
    /// A fresh agent for `state_dim`-dimensional states and
    /// `action_dim`-dimensional actions, seeded deterministically.
    pub fn new(state_dim: usize, action_dim: usize, cfg: DdpgConfig, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xddb6);
        let (h1, h2) = cfg.hidden;
        let actor = Mlp::new(
            &[state_dim, h1, h2, action_dim],
            &[Activation::Relu, Activation::Relu, Activation::Sigmoid],
            &mut rng,
        );
        let critic = Mlp::new(
            &[state_dim + action_dim, h1, h2, 1],
            &[Activation::Relu, Activation::Relu, Activation::Linear],
            &mut rng,
        );
        let actor_target = actor.clone();
        let critic_target = critic.clone();
        let actor_opt = Adam::new(&actor, cfg.actor_lr);
        let critic_opt = Adam::new(&critic, cfg.critic_lr);
        Self {
            replay: ReplayBuffer::new(cfg.replay_capacity),
            state_norm: RunningNorm::new(state_dim),
            reward_mean: Ema::new(cfg.reward_ema),
            reward_scale: Ema::new(cfg.reward_ema),
            sigma: cfg.sigma0,
            actor,
            critic,
            actor_target,
            critic_target,
            actor_opt,
            critic_opt,
            rng,
            state_dim,
            action_dim,
            cfg,
            ws: OptimizeWorkspace::default(),
        }
    }

    /// Dimension of the states the agent expects.
    pub fn state_dim(&self) -> usize {
        self.state_dim
    }

    /// Dimension of the actions the agent emits.
    pub fn action_dim(&self) -> usize {
        self.action_dim
    }

    fn normalized(&self, state: &[f32]) -> Vec<f32> {
        let mut s = state.to_vec();
        self.state_norm.normalize(&mut s);
        s
    }

    /// Predict an action for one state.
    /// `explore`: add Eq. 7 truncated-normal noise around the actor output.
    /// `random`: ignore the actor entirely (warm-up episodes).
    pub fn act(&mut self, state: &[f32], explore: bool, random: bool) -> Vec<f32> {
        assert_eq!(state.len(), self.state_dim);
        self.state_norm.update(state);
        if random {
            return (0..self.action_dim)
                .map(|_| self.rng.next_f64() as f32)
                .collect();
        }
        let s = self.normalized(state);
        let mu = self.actor.forward1(&s);
        if !explore {
            return mu;
        }
        mu.into_iter()
            .map(|m| self.rng.truncated_normal(m as f64, self.sigma, 0.0, 1.0) as f32)
            .collect()
    }

    /// Append a transition to the replay buffer.
    pub fn store(&mut self, t: Transition) {
        self.replay.push(t);
    }

    /// End-of-episode: decay exploration noise.
    pub fn end_episode(&mut self) {
        self.sigma *= self.cfg.sigma_decay;
    }

    /// One optimization step (critic TD + actor policy gradient + soft
    /// target updates) on a replay minibatch.  Returns (critic_loss, mean_q).
    ///
    /// All intermediates live in a per-agent workspace, so the steady-state
    /// step performs no heap allocation (see
    /// `workspace_fingerprint` and the regression test that pins it).
    pub fn optimize(&mut self) -> Option<(f32, f32)> {
        let batch_n = self.cfg.batch.min(self.replay.len());
        if batch_n < 8 {
            return None;
        }
        // ---- assemble batch into the workspace (normalized states) ----
        self.replay
            .sample_into(batch_n, &mut self.rng, &mut self.ws.idx);
        let ws = &mut self.ws;
        ws.states.reshape_to(batch_n, self.state_dim);
        ws.actions.reshape_to(batch_n, self.action_dim);
        ws.next_states.reshape_to(batch_n, self.state_dim);
        ws.rewards.clear();
        ws.terminals.clear();
        for (r, &i) in ws.idx.iter().enumerate() {
            let t = self.replay.get(i);
            let srow = ws.states.row_mut(r);
            srow.copy_from_slice(&t.state);
            self.state_norm.normalize(srow);
            let nrow = ws.next_states.row_mut(r);
            nrow.copy_from_slice(&t.next_state);
            self.state_norm.normalize(nrow);
            ws.actions.row_mut(r).copy_from_slice(&t.action);
            ws.rewards.push(t.reward);
            ws.terminals.push(t.terminal);
        }

        // reward normalization by moving average (paper §Proposed Agents)
        let batch_mean = ws.rewards.iter().sum::<f32>() as f64 / ws.rewards.len() as f64;
        let mean = self.reward_mean.update(batch_mean);
        let batch_scale = ws
            .rewards
            .iter()
            .map(|&r| (r as f64 - mean).abs())
            .sum::<f64>()
            / ws.rewards.len() as f64;
        let scale = self.reward_scale.update(batch_scale).max(1e-3);

        // ---- critic update: y = r + gamma * Q'(s', mu'(s')) ----
        self.actor_target
            .forward_cached_ws(&ws.next_states, &mut ws.actor_tgt_ws);
        ws.next_states
            .hcat_into(ws.actor_tgt_ws.output(), &mut ws.next_sa);
        self.critic_target
            .forward_cached_ws(&ws.next_sa, &mut ws.critic_tgt_ws);
        ws.y.reshape_to(batch_n, 1);
        {
            let q_next = ws.critic_tgt_ws.output();
            for i in 0..batch_n {
                let bootstrap = if ws.terminals[i] {
                    0.0
                } else {
                    self.cfg.gamma * q_next.at(i, 0)
                };
                let norm_r = ((ws.rewards[i] as f64 - mean) / scale) as f32;
                *ws.y.at_mut(i, 0) = norm_r + bootstrap;
            }
        }
        ws.states.hcat_into(&ws.actions, &mut ws.sa);
        self.critic.forward_cached_ws(&ws.sa, &mut ws.critic_ws);
        ws.dout.reshape_to(batch_n, 1);
        let mut critic_loss = 0.0f32;
        {
            let q = ws.critic_ws.output();
            for i in 0..batch_n {
                let d = q.at(i, 0) - ws.y.at(i, 0);
                critic_loss += d * d / batch_n as f32;
                *ws.dout.at_mut(i, 0) = 2.0 * d / batch_n as f32;
            }
        }
        self.critic.backward_ws(&mut ws.critic_ws, &ws.dout);
        Mlp::clip_grads(&mut ws.critic_ws.grads, self.cfg.grad_clip);
        self.critic_opt.step(&mut self.critic, &ws.critic_ws.grads);

        // ---- actor update: ascend Q(s, mu(s)) ----
        self.actor.forward_cached_ws(&ws.states, &mut ws.actor_ws);
        ws.states.hcat_into(ws.actor_ws.output(), &mut ws.sa_mu);
        self.critic.forward_cached_ws(&ws.sa_mu, &mut ws.critic_ws);
        let mean_q = ws.critic_ws.output().mean();
        // dLoss/dQ = -1/N (maximize Q)
        ws.dq.reshape_to(batch_n, 1);
        ws.dq.data.fill(-1.0 / batch_n as f32);
        self.critic.backward_ws(&mut ws.critic_ws, &ws.dq);
        ws.critic_ws
            .input_grad()
            .split_right_into(self.state_dim, &mut ws.da);
        self.actor.backward_ws(&mut ws.actor_ws, &ws.da);
        Mlp::clip_grads(&mut ws.actor_ws.grads, self.cfg.grad_clip);
        self.actor_opt.step(&mut self.actor, &ws.actor_ws.grads);

        // ---- soft target updates ----
        self.actor_target.soft_update_from(&self.actor, self.cfg.tau);
        self.critic_target
            .soft_update_from(&self.critic, self.cfg.tau);

        Some((critic_loss, mean_q))
    }

    /// Serialize the complete agent — all four networks, both Adam states,
    /// the replay buffer, reward/state normalizers, exploration sigma, and
    /// the live RNG stream.  An agent restored via [`Ddpg::restore`]
    /// produces bit-identical actions and optimization steps to this one,
    /// which is what makes driver checkpoints resumable without drift.
    pub fn checkpoint(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("cfg", self.cfg.to_json()),
            ("state_dim", Json::num(self.state_dim as f64)),
            ("action_dim", Json::num(self.action_dim as f64)),
            ("sigma", Json::num(self.sigma)),
            ("rng", self.rng.to_json()),
            ("actor", self.actor.to_json()),
            ("critic", self.critic.to_json()),
            ("actor_target", self.actor_target.to_json()),
            ("critic_target", self.critic_target.to_json()),
            ("actor_opt", self.actor_opt.to_json()),
            ("critic_opt", self.critic_opt.to_json()),
            ("replay", self.replay.to_json()),
            ("state_norm", self.state_norm.to_json()),
            ("reward_mean", self.reward_mean.to_json()),
            ("reward_scale", self.reward_scale.to_json()),
        ])
    }

    /// Rebuild an agent serialized by [`Ddpg::checkpoint`].  The optimize
    /// workspace is rebuilt empty — it is pure scratch, fully overwritten
    /// by each step, so this does not affect the trajectory.
    pub fn restore(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let cfg = DdpgConfig::from_json(j.req("cfg")?)?;
        let actor = Mlp::from_json(j.req("actor")?)?;
        let critic = Mlp::from_json(j.req("critic")?)?;
        let actor_target = Mlp::from_json(j.req("actor_target")?)?;
        let critic_target = Mlp::from_json(j.req("critic_target")?)?;
        let actor_opt = Adam::from_json(j.req("actor_opt")?, &actor)?;
        let critic_opt = Adam::from_json(j.req("critic_opt")?, &critic)?;
        let state_dim = j.req_usize("state_dim")?;
        let action_dim = j.req_usize("action_dim")?;
        anyhow::ensure!(
            actor.input_dim() == state_dim && actor.output_dim() == action_dim,
            "checkpoint actor shape does not match its recorded dimensions"
        );
        anyhow::ensure!(
            critic.input_dim() == state_dim + action_dim && critic.output_dim() == 1,
            "checkpoint critic shape does not match its recorded dimensions"
        );
        // target networks and replay transitions feed optimize() without
        // further checks, so a malformed checkpoint must fail here (Err),
        // not panic layers deep into the first optimization step
        let same_shape = |a: &Mlp, b: &Mlp| {
            a.layers.len() == b.layers.len()
                && a.layers.iter().zip(&b.layers).all(|(x, y)| {
                    x.w.rows == y.w.rows && x.w.cols == y.w.cols && x.b.len() == y.b.len()
                })
        };
        anyhow::ensure!(
            same_shape(&actor, &actor_target),
            "checkpoint actor_target shape does not match the actor"
        );
        anyhow::ensure!(
            same_shape(&critic, &critic_target),
            "checkpoint critic_target shape does not match the critic"
        );
        let replay = ReplayBuffer::from_json(j.req("replay")?)?;
        for i in 0..replay.len() {
            let t = replay.get(i);
            anyhow::ensure!(
                t.state.len() == state_dim
                    && t.next_state.len() == state_dim
                    && t.action.len() == action_dim,
                "checkpoint replay transition {i} has mismatched dimensions"
            );
        }
        let state_norm = RunningNorm::from_json(j.req("state_norm")?)?;
        anyhow::ensure!(state_norm.dim() == state_dim, "checkpoint state-norm dimension mismatch");
        Ok(Self {
            replay,
            state_norm,
            reward_mean: Ema::from_json(j.req("reward_mean")?)?,
            reward_scale: Ema::from_json(j.req("reward_scale")?)?,
            sigma: j.req_f64("sigma")?,
            actor,
            critic,
            actor_target,
            critic_target,
            actor_opt,
            critic_opt,
            rng: Pcg64::from_json(j.req("rng")?)?,
            state_dim,
            action_dim,
            cfg,
            ws: OptimizeWorkspace::default(),
        })
    }

    /// (pointer, capacity) of every `optimize` workspace buffer.  After a
    /// warm-up step at a stable batch shape these must not change — the
    /// zero-allocation regression test pins exactly that.
    pub fn workspace_fingerprint(&self) -> Vec<(usize, usize)> {
        let ws = &self.ws;
        let mut out = vec![
            (ws.idx.as_ptr() as usize, ws.idx.capacity()),
            (ws.rewards.as_ptr() as usize, ws.rewards.capacity()),
            (ws.terminals.as_ptr() as usize, ws.terminals.capacity()),
        ];
        for m in [
            &ws.states,
            &ws.actions,
            &ws.next_states,
            &ws.sa,
            &ws.next_sa,
            &ws.sa_mu,
            &ws.y,
            &ws.dout,
            &ws.dq,
            &ws.da,
        ] {
            out.push((m.data.as_ptr() as usize, m.data.capacity()));
        }
        for t in [
            &ws.actor_ws,
            &ws.critic_ws,
            &ws.actor_tgt_ws,
            &ws.critic_tgt_ws,
        ] {
            out.extend(t.buffer_fingerprint());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(state_dim: usize, action_dim: usize, seed: u64) -> Ddpg {
        Ddpg::new(
            state_dim,
            action_dim,
            DdpgConfig {
                hidden: (32, 24),
                batch: 16,
                replay_capacity: 512,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn actions_in_unit_interval() {
        let mut agent = mk(4, 2, 1);
        for i in 0..50 {
            let s = vec![i as f32, -1.0, 0.5, 2.0];
            for &(e, r) in &[(false, false), (true, false), (false, true)] {
                let a = agent.act(&s, e, r);
                assert_eq!(a.len(), 2);
                assert!(a.iter().all(|&x| (0.0..=1.0).contains(&x)), "{a:?}");
            }
        }
    }

    #[test]
    fn noise_decays() {
        let mut agent = mk(2, 1, 2);
        let s0 = agent.sigma;
        for _ in 0..10 {
            agent.end_episode();
        }
        assert!((agent.sigma - s0 * 0.95f64.powi(10)).abs() < 1e-12);
    }

    #[test]
    fn optimize_needs_data() {
        let mut agent = mk(2, 1, 3);
        assert!(agent.optimize().is_none());
    }

    /// End-to-end learning sanity: a 1-step bandit where reward = 1 - |a - 0.7|.
    /// After training, the deterministic policy should act near 0.7.
    #[test]
    fn learns_simple_bandit() {
        let mut agent = mk(2, 1, 4);
        let state = vec![0.3f32, -0.2];
        let mut rng = Pcg64::new(77);
        for ep in 0..600 {
            let random = ep < 40;
            let a = agent.act(&state, true, random);
            let reward = 1.0 - (a[0] - 0.7).abs();
            agent.store(Transition {
                state: state.clone(),
                action: a,
                reward,
                next_state: state.clone(),
                terminal: true,
            });
            agent.end_episode();
            if ep >= 40 {
                agent.optimize();
            }
            let _ = &mut rng;
        }
        let a = agent.act(&state, false, false);
        assert!(
            (a[0] - 0.7).abs() < 0.15,
            "expected action near 0.7, got {}",
            a[0]
        );
    }

    /// Zero-allocation steady state: after a warm-up step has sized the
    /// workspace, further optimize steps must reuse every buffer in place
    /// (stable pointers and capacities).
    #[test]
    fn optimize_workspace_stable_across_steps() {
        let mut agent = mk(4, 2, 9);
        let mut rng = Pcg64::new(31);
        for _ in 0..64 {
            let s: Vec<f32> = (0..4).map(|_| rng.next_f32()).collect();
            let a: Vec<f32> = (0..2).map(|_| rng.next_f32()).collect();
            agent.store(Transition {
                state: s.clone(),
                action: a,
                reward: rng.next_f32(),
                next_state: s,
                terminal: rng.below(4) == 0,
            });
        }
        for _ in 0..3 {
            agent.optimize().expect("enough data to optimize");
        }
        let fp = agent.workspace_fingerprint();
        assert!(!fp.is_empty());
        for _ in 0..10 {
            agent.optimize().unwrap();
        }
        assert_eq!(
            fp,
            agent.workspace_fingerprint(),
            "optimize reallocated workspace buffers at steady state"
        );
    }

    /// The checkpoint/restore contract: a restored agent and the original
    /// take bit-identical actions and optimization steps from the snapshot
    /// point onward (exploration noise included — the RNG stream resumes).
    #[test]
    fn checkpoint_restore_continues_bit_identically() {
        use crate::util::json::Json;
        let mut agent = mk(4, 2, 17);
        let mut rng = Pcg64::new(23);
        for _ in 0..48 {
            let s: Vec<f32> = (0..4).map(|_| rng.next_f32()).collect();
            let a = agent.act(&s, true, false);
            agent.store(Transition {
                state: s.clone(),
                action: a,
                reward: rng.next_f32(),
                next_state: s,
                terminal: rng.below(5) == 0,
            });
            agent.optimize();
        }
        agent.end_episode();
        // round-trip through serialized text, exactly as a checkpoint file
        let text = agent.checkpoint().dump();
        let mut restored = Ddpg::restore(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(restored.sigma.to_bits(), agent.sigma.to_bits());
        assert_eq!(restored.replay.len(), agent.replay.len());
        for step in 0..20 {
            let s: Vec<f32> = (0..4).map(|_| rng.next_f32()).collect();
            let a1 = agent.act(&s, true, false);
            let a2 = restored.act(&s, true, false);
            for (x, y) in a1.iter().zip(&a2) {
                assert_eq!(x.to_bits(), y.to_bits(), "step {step} diverged");
            }
            let t = Transition {
                state: s.clone(),
                action: a1,
                reward: 0.25,
                next_state: s,
                terminal: step % 3 == 0,
            };
            agent.store(t.clone());
            restored.store(t);
            let o1 = agent.optimize();
            let o2 = restored.optimize();
            match (o1, o2) {
                (Some((l1, q1)), Some((l2, q2))) => {
                    assert_eq!(l1.to_bits(), l2.to_bits());
                    assert_eq!(q1.to_bits(), q2.to_bits());
                }
                (a, b) => assert_eq!(a.is_some(), b.is_some()),
            }
        }
    }

    #[test]
    fn critic_loss_decreases_on_fixed_batch() {
        let mut agent = mk(3, 2, 5);
        let mut rng = Pcg64::new(9);
        for _ in 0..64 {
            let s: Vec<f32> = (0..3).map(|_| rng.next_f32()).collect();
            let a: Vec<f32> = (0..2).map(|_| rng.next_f32()).collect();
            let r = s[0] + a[0];
            agent.store(Transition {
                state: s.clone(),
                action: a,
                reward: r,
                next_state: s,
                terminal: true,
            });
        }
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..200 {
            if let Some((loss, _)) = agent.optimize() {
                first.get_or_insert(loss);
                last = loss;
            }
        }
        assert!(
            last < first.unwrap(),
            "critic loss should fall: first={first:?} last={last}"
        );
    }
}
