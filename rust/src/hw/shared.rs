//! Thread-safe shared latency caches for concurrent searches.
//!
//! The parallel sweep orchestrator (`search::orchestrator`) runs many
//! `run_search` jobs at once, each with its own `LatencyProvider`.  Most of
//! those searches probe overlapping layer configurations, so per-provider
//! caches would re-derive (simulator) or re-measure (profiler) the same
//! entries once per worker.  These handles put one `Arc<RwLock<HashMap>>`
//! behind every provider of a sweep: the first provider to resolve a
//! configuration publishes it, and every other worker reuses the published
//! value.
//!
//! Sharing never changes results for the analytical simulator — its
//! per-layer costs are pure functions of the configuration — and for the
//! measured profiler the first published measurement becomes canonical
//! (`SharedProfileCache::insert_or_get`), so all workers of one sweep score
//! a given configuration with the same number.
//!
//! Accesses go through the poison-recovering `util::sync` helpers: a
//! worker that panics mid-publish leaves at worst one unpublished entry,
//! never a poison that cascades into every other job of the service.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::util::sync;

use super::profiler::ProfileEntry;

/// Shared memo of deterministic per-layer simulator costs, keyed by a hash
/// of `(IR fingerprint, layer, eff_cin, kept_channels, quant_mode)`.
///
/// Cloning the handle shares the underlying map (it is an `Arc`); attach a
/// clone to each `LatencySimulator` of a sweep via
/// `LatencySimulator::with_shared_cache`.
#[derive(Clone, Debug, Default)]
pub struct SharedCostCache {
    inner: Arc<RwLock<HashMap<u64, f64>>>,
}

impl SharedCostCache {
    /// An empty cache handle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Published cost for `key`, if any worker has resolved it.
    pub fn get(&self, key: u64) -> Option<f64> {
        sync::read(&self.inner).get(&key).copied()
    }

    /// Publish a resolved cost.  Values are pure functions of the key, so
    /// concurrent double-inserts write the same number and either wins.
    pub fn insert(&self, key: u64, value: f64) {
        sync::write(&self.inner).insert(key, value);
    }

    /// Number of published entries.
    pub fn len(&self) -> usize {
        sync::read(&self.inner).len()
    }

    /// Whether no entry has been published yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Shared measured-profile entries, keyed by the profiler's config hash
/// (`hw::profiler::config_key`).
///
/// Unlike simulator costs, measurements carry timing jitter, so the *first*
/// published entry is canonical: `insert_or_get` never overwrites, and every
/// worker that races on the same configuration walks away with the same
/// `ProfileEntry`.
#[derive(Clone, Debug, Default)]
pub struct SharedProfileCache {
    inner: Arc<RwLock<HashMap<u64, ProfileEntry>>>,
}

impl SharedProfileCache {
    /// An empty cache handle.
    pub fn new() -> Self {
        Self::default()
    }

    /// The canonical entry for `key`, if one was published.
    pub fn get(&self, key: u64) -> Option<ProfileEntry> {
        sync::read(&self.inner).get(&key).cloned()
    }

    /// Publish `entry` unless some worker beat us to it; returns the
    /// canonical entry either way.
    pub fn insert_or_get(&self, key: u64, entry: ProfileEntry) -> ProfileEntry {
        sync::write(&self.inner).entry(key).or_insert(entry).clone()
    }

    /// A point-in-time copy of every published entry (used to fold a
    /// sweep's measurements into one disk manifest after the barrier).
    pub fn snapshot(&self) -> Vec<(u64, ProfileEntry)> {
        sync::read(&self.inner)
            .iter()
            .map(|(k, v)| (*k, v.clone()))
            .collect()
    }

    /// Number of published entries.
    pub fn len(&self) -> usize {
        sync::read(&self.inner).len()
    }

    /// Whether no entry has been published yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(latency_s: f64) -> ProfileEntry {
        ProfileEntry {
            latency_s,
            mad_s: 0.0,
            samples: 1,
            layer: "l".into(),
            mode: "FP32".into(),
            degraded: false,
        }
    }

    #[test]
    fn cost_cache_roundtrip_and_clone_shares() {
        let a = SharedCostCache::new();
        let b = a.clone();
        assert!(a.is_empty());
        a.insert(7, 1.5);
        assert_eq!(b.get(7), Some(1.5));
        assert_eq!(b.len(), 1);
        assert_eq!(b.get(8), None);
    }

    #[test]
    fn profile_cache_first_insert_is_canonical() {
        let c = SharedProfileCache::new();
        let first = c.insert_or_get(1, entry(2.0));
        assert_eq!(first.latency_s, 2.0);
        // a racing second measurement must NOT displace the canonical one
        let second = c.insert_or_get(1, entry(3.0));
        assert_eq!(second.latency_s, 2.0);
        assert_eq!(c.get(1).unwrap().latency_s, 2.0);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn profile_cache_snapshot_copies_entries() {
        let c = SharedProfileCache::new();
        c.insert_or_get(1, entry(1.0));
        c.insert_or_get(2, entry(2.0));
        let mut snap = c.snapshot();
        snap.sort_by_key(|(k, _)| *k);
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, 1);
        assert_eq!(snap[1].1.latency_s, 2.0);
    }

    #[test]
    fn concurrent_writers_settle_on_one_value() {
        let c = SharedProfileCache::new();
        std::thread::scope(|scope| {
            for t in 0..8 {
                let c = c.clone();
                scope.spawn(move || {
                    for k in 0..16u64 {
                        c.insert_or_get(k, entry((t * 100 + k) as f64));
                    }
                });
            }
        });
        assert_eq!(c.len(), 16);
        // every reader agrees with the canonical entry
        for k in 0..16u64 {
            let v = c.get(k).unwrap().latency_s;
            assert_eq!(c.insert_or_get(k, entry(-1.0)).latency_s, v);
        }
    }
}
