//! TVM bit-serial operator constraints (paper §Direct Metric): mixed
//! precision is only available to layers whose *compressed* configuration
//! satisfies the operator's layout requirements.  Unsupported layers fall
//! back to INT8 when the agent asks for MIX.

use crate::model::{Layer, LayerKind};

/// Can this layer run the bit-serial (MIX) operators, given its effective
/// (post-pruning) channel counts?
///
/// Conv: input channels % 32 == 0, output channels % 8 == 0, spatial output
/// dimension >= 2, not depthwise.  Linear: output features % 8 == 0.
pub fn mix_supported(layer: &Layer, eff_cin: usize, eff_cout: usize) -> bool {
    match layer.kind {
        LayerKind::Conv => {
            !layer.depthwise
                && eff_cin % 32 == 0
                && eff_cin > 0
                && eff_cout % 8 == 0
                && eff_cout > 0
                && layer.out_spatial >= 2
        }
        LayerKind::Linear => eff_cout % 8 == 0 && eff_cout > 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;

    fn conv(cin: usize, cout: usize, out_spatial: usize, depthwise: bool) -> Layer {
        Layer {
            index: 0,
            name: "t".into(),
            kind: LayerKind::Conv,
            cin,
            cout,
            kernel: 3,
            stride: 1,
            in_spatial: out_spatial,
            out_spatial,
            prunable: true,
            group: -1,
            depthwise,
        }
    }

    #[test]
    fn conv_constraints() {
        assert!(mix_supported(&conv(32, 64, 8, false), 32, 64));
        // first layer (cin=3) can never be MIX — matches paper Fig 3b
        assert!(!mix_supported(&conv(3, 32, 32, false), 3, 32));
        assert!(!mix_supported(&conv(32, 64, 8, false), 16, 64)); // pruned producer broke %32
        assert!(!mix_supported(&conv(32, 64, 8, false), 32, 60)); // cout % 8
        assert!(!mix_supported(&conv(32, 64, 1, false), 32, 64)); // spatial < 2
        assert!(!mix_supported(&conv(32, 32, 8, true), 32, 32)); // depthwise
    }

    #[test]
    fn linear_constraints() {
        let mut fc = conv(256, 10, 1, false);
        fc.kind = LayerKind::Linear;
        // classifier with 10 outputs is not a multiple of 8 => INT8 fallback,
        // exactly the paper's "last layer is INT8 by constraint"
        assert!(!mix_supported(&fc, 256, 10));
        fc.cout = 16;
        assert!(mix_supported(&fc, 256, 16));
    }

    #[test]
    fn mobilenetv2s_depthwise_layers_never_mix() {
        // the zoo's depthwise convs satisfy every *numeric* constraint
        // (channels are multiples of 32, spatial >= 2) — only the depthwise
        // exclusion keeps them off the bit-serial path
        let ir = crate::model::ModelIr::from_meta(
            &crate::model::zoo::meta("mobilenetv2s").unwrap(),
        )
        .unwrap();
        let dws: Vec<_> = ir.layers.iter().filter(|l| l.depthwise).collect();
        assert!(!dws.is_empty());
        for l in dws {
            assert!(!mix_supported(l, l.cin, l.cout), "{}", l.name);
            if l.cin % 32 == 0 && l.cout % 8 == 0 && l.out_spatial >= 2 {
                // flipping only the flag flips the verdict
                let mut dense = (*l).clone();
                dense.depthwise = false;
                assert!(mix_supported(&dense, l.cin, l.cout), "{}", l.name);
            }
        }
    }

    #[test]
    fn mobilenetv2s_group_layout_couples_expand_and_project() {
        use crate::compress::DiscretePolicy;
        let ir = crate::model::ModelIr::from_meta(
            &crate::model::zoo::meta("mobilenetv2s").unwrap(),
        )
        .unwrap();
        // expand -> dw -> project coupling through effective_cin: pruning
        // the expand shrinks what the depthwise and project layers read
        let expand = ir.layer_by_name("s1b1.expand").unwrap().index;
        let dw = ir.layer_by_name("s1b1.dw").unwrap().index;
        let project = ir.layer_by_name("s1b1.project").unwrap().index;
        let mut p = DiscretePolicy::reference(&ir);
        p.layers[expand].kept_channels = 40;
        p.layers[dw].kept_channels = 40; // the mapper keeps these in lockstep
        assert_eq!(p.effective_cin(&ir, dw), 40);
        assert_eq!(p.effective_cin(&ir, project), 40);
        // project outputs are stream-coupled: group members share a width
        // and none is independently prunable
        for members in ir.groups.values() {
            let w = ir.layers[members[0]].cout;
            for &i in members {
                assert_eq!(ir.layers[i].cout, w);
                assert!(!ir.layers[i].prunable, "{}", ir.layers[i].name);
            }
        }
        // a depthwise layer's channel count follows its group's (stream's)
        // producer chain, not the stream width itself
        assert_eq!(ir.layers[dw].cin, ir.layers[expand].cout);
    }
}
