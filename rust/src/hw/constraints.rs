//! TVM bit-serial operator constraints (paper §Direct Metric): mixed
//! precision is only available to layers whose *compressed* configuration
//! satisfies the operator's layout requirements.  Unsupported layers fall
//! back to INT8 when the agent asks for MIX.

use crate::model::{Layer, LayerKind};

/// Can this layer run the bit-serial (MIX) operators, given its effective
/// (post-pruning) channel counts?
///
/// Conv: input channels % 32 == 0, output channels % 8 == 0, spatial output
/// dimension >= 2, not depthwise.  Linear: output features % 8 == 0.
pub fn mix_supported(layer: &Layer, eff_cin: usize, eff_cout: usize) -> bool {
    match layer.kind {
        LayerKind::Conv => {
            !layer.depthwise
                && eff_cin % 32 == 0
                && eff_cin > 0
                && eff_cout % 8 == 0
                && eff_cout > 0
                && layer.out_spatial >= 2
        }
        LayerKind::Linear => eff_cout % 8 == 0 && eff_cout > 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;

    fn conv(cin: usize, cout: usize, out_spatial: usize, depthwise: bool) -> Layer {
        Layer {
            index: 0,
            name: "t".into(),
            kind: LayerKind::Conv,
            cin,
            cout,
            kernel: 3,
            stride: 1,
            in_spatial: out_spatial,
            out_spatial,
            prunable: true,
            group: -1,
            depthwise,
        }
    }

    #[test]
    fn conv_constraints() {
        assert!(mix_supported(&conv(32, 64, 8, false), 32, 64));
        // first layer (cin=3) can never be MIX — matches paper Fig 3b
        assert!(!mix_supported(&conv(3, 32, 32, false), 3, 32));
        assert!(!mix_supported(&conv(32, 64, 8, false), 16, 64)); // pruned producer broke %32
        assert!(!mix_supported(&conv(32, 64, 8, false), 32, 60)); // cout % 8
        assert!(!mix_supported(&conv(32, 64, 1, false), 32, 64)); // spatial < 2
        assert!(!mix_supported(&conv(32, 32, 8, true), 32, 32)); // depthwise
    }

    #[test]
    fn linear_constraints() {
        let mut fc = conv(256, 10, 1, false);
        fc.kind = LayerKind::Linear;
        // classifier with 10 outputs is not a multiple of 8 => INT8 fallback,
        // exactly the paper's "last layer is INT8 by constraint"
        assert!(!mix_supported(&fc, 256, 10));
        fc.cout = 16;
        assert!(mix_supported(&fc, 256, 16));
    }
}
