//! Hardware substrate: an analytical latency simulator of the paper's
//! target (Raspberry Pi 4B, ARM Cortex-A72, TVM-generated fp32 / int8 /
//! bit-serial operators) **and** a measured-latency profiler that actually
//! executes quantized kernels and times them.
//!
//! The paper measures each candidate policy's inference latency on the
//! physical device; this environment has no Pi, so — per the substitution
//! rule in DESIGN.md — we implement the closest synthetic equivalent that
//! exercises the same code path: `LatencySimulator::measure` consumes a
//! `DiscretePolicy` exactly as TVM would consume the restructured model and
//! returns a latency scalar with measurement noise (repeat + median).
//!
//! Since PR 2 the measurement half is real as well: `MeasuredProfiler`
//! lowers each layer configuration to the in-tree f32 / i8 / packed-i8 GEMM
//! kernels (`tensor::quant`) and measures steady-state host latency behind
//! a versioned on-disk profile cache.  Both backends (plus the calibrated
//! `HybridProvider`) implement `LatencyProvider`, the pluggable latency
//! interface of `search::run_search` (`--latency sim|measured|hybrid`).
//!
//! For parallel sweeps (`search::run_sweep`), both backends accept shared
//! cross-worker caches (`SharedCostCache` / `SharedProfileCache`) so
//! concurrent searches reuse each other's per-layer costs and kernel
//! measurements instead of re-deriving them.
//!
//! The cost model reproduces the qualitative structure the search dynamics
//! depend on (calibration tests in `cost.rs` / `sim.rs`):
//!
//! * latency is **not** proportional to MACs or BOPs: cache-boundness makes
//!   large layers disproportionately expensive (Klein et al. 2021);
//! * INT8 beats FP32 by ~2-3x minus (re)quantization overheads;
//! * bit-serial MIX scales with `w_bits * a_bits` plus bit-packing overhead
//!   and crosses over INT8 near 6x6 bits (paper §Exploration Range);
//! * the TVM bit-serial operator constraints gate MIX per layer
//!   (in_ch % 32, out_ch % 8, spatial >= 2, no depthwise, linear out % 8).

mod constraints;
mod cost;
mod profiler;
mod provider;
mod shared;
mod sim;
mod target;

pub use constraints::mix_supported;
pub use cost::{CostModel, LayerCost};
pub use profiler::{
    MeasuredProfiler, ProfileEntry, ProfilerConfig, ProfilerStats, PROFILE_SCHEMA_VERSION,
};
pub(crate) use profiler::sanitize;
pub use provider::{HybridProvider, LatencyKind, LatencyProvider};
pub use shared::{SharedCostCache, SharedProfileCache};
pub use sim::{LatencySimulator, Measurement};
pub use target::HwTarget;
