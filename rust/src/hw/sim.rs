//! Whole-model latency simulator with a measurement harness.
//!
//! `latency()` is the deterministic cost-model sum over the policy's
//! effective layer configurations.  `measure()` mimics the paper's TVM
//! remote measurement: N noisy repetitions, median-reduced — so the reward
//! the agent sees carries realistic measurement jitter.

use super::cost::CostModel;
use crate::compress::DiscretePolicy;
use crate::model::ModelIr;
use crate::util::rng::Pcg64;
use crate::util::stats::median;

/// One latency measurement (seconds) with its raw samples.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub latency_s: f64,
    pub samples: Vec<f64>,
}

#[derive(Clone, Debug)]
pub struct LatencySimulator {
    pub cost: CostModel,
    /// Relative Gaussian measurement noise per repetition (sigma).
    pub noise_sigma: f64,
    /// Repetitions per measurement (median-reduced).
    pub repeats: usize,
    rng: Pcg64,
}

impl LatencySimulator {
    pub fn new(cost: CostModel, seed: u64) -> Self {
        Self {
            cost,
            noise_sigma: 0.01,
            repeats: 5,
            rng: Pcg64::with_stream(seed, 0x1a7e),
        }
    }

    /// Deterministic (noise-free) end-to-end latency of a compressed model.
    pub fn latency(&self, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        let mut total = 0.0;
        for l in &ir.layers {
            let cmp = &policy.layers[l.index];
            let eff_cin = policy.effective_cin(ir, l.index);
            total += self
                .cost
                .layer_cost(l, eff_cin, cmp.kept_channels, cmp.quant)
                .total();
        }
        total
    }

    /// Per-layer deterministic latency breakdown (profiling / Fig analysis).
    pub fn latency_per_layer(&self, ir: &ModelIr, policy: &DiscretePolicy) -> Vec<f64> {
        ir.layers
            .iter()
            .map(|l| {
                let cmp = &policy.layers[l.index];
                let eff_cin = policy.effective_cin(ir, l.index);
                self.cost
                    .layer_cost(l, eff_cin, cmp.kept_channels, cmp.quant)
                    .total()
            })
            .collect()
    }

    /// Noisy measurement: repeat + median, like the on-device harness.
    pub fn measure(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> Measurement {
        let base = self.latency(ir, policy);
        let samples: Vec<f64> = (0..self.repeats)
            .map(|_| {
                let noise = 1.0 + self.noise_sigma * self.rng.normal();
                // measurement noise is one-sided-ish in practice (preemption
                // only ever slows you down); fold extreme negatives
                base * noise.max(1.0 - 2.0 * self.noise_sigma)
            })
            .collect();
        Measurement {
            latency_s: median(&samples),
            samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::QuantMode;
    use crate::hw::HwTarget;
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn setup() -> (ModelIr, LatencySimulator) {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 7);
        (ir, sim)
    }

    #[test]
    fn reference_latency_positive_and_deterministic() {
        let (ir, sim) = setup();
        let p = DiscretePolicy::reference(&ir);
        let a = sim.latency(&ir, &p);
        let b = sim.latency(&ir, &p);
        assert!(a > 0.0);
        assert_eq!(a, b);
        let per_layer = sim.latency_per_layer(&ir, &p);
        assert_eq!(per_layer.len(), ir.layers.len());
        assert!((per_layer.iter().sum::<f64>() - a).abs() < 1e-12);
    }

    #[test]
    fn compression_reduces_latency() {
        let (ir, sim) = setup();
        let reference = DiscretePolicy::reference(&ir);
        let base = sim.latency(&ir, &reference);

        let mut pruned = reference.clone();
        pruned.layers[1].kept_channels = 2;
        pruned.layers[3].kept_channels = 4;
        assert!(sim.latency(&ir, &pruned) < base);

        let mut quant = reference.clone();
        for l in &mut quant.layers {
            l.quant = QuantMode::Int8;
        }
        assert!(sim.latency(&ir, &quant) < base);
    }

    #[test]
    fn measurement_noise_bounded_and_seeded() {
        let (ir, _) = setup();
        let p = DiscretePolicy::reference(&ir);
        let mut sim1 = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 42);
        let mut sim2 = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 42);
        let base = sim1.latency(&ir, &p);
        let m1 = sim1.measure(&ir, &p);
        let m2 = sim2.measure(&ir, &p);
        assert_eq!(m1.latency_s, m2.latency_s, "seeded determinism");
        assert_eq!(m1.samples.len(), 5);
        assert!((m1.latency_s / base - 1.0).abs() < 0.1);
    }

    #[test]
    fn float_only_target_ignores_quant_modes() {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sim = LatencySimulator::new(
            CostModel::new(HwTarget::cortex_a72().float_only()),
            3,
        );
        let reference = DiscretePolicy::reference(&ir);
        let mut quant = reference.clone();
        for l in &mut quant.layers {
            l.quant = QuantMode::Int8;
        }
        // on a float-only device quantization buys nothing
        let a = sim.latency(&ir, &reference);
        let b = sim.latency(&ir, &quant);
        assert_eq!(a, b);
    }
}
