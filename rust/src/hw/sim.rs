//! Whole-model latency simulator with a measurement harness.
//!
//! `latency()` is the deterministic cost-model sum over the policy's
//! effective layer configurations.  `measure()` mimics the paper's TVM
//! remote measurement: N noisy repetitions, median-reduced — so the reward
//! the agent sees carries realistic measurement jitter.  The jitter is a
//! pure function of `(seed, ir, policy)`, not of call order: probing the
//! same configuration twice (or in a different episode order) returns the
//! identical measurement, which keeps hybrid calibration and tests
//! reproducible.
//!
//! Per-layer costs are memoized keyed by
//! `(layer_index, effective_cin, kept_channels, quant_mode)`: the episode
//! loop perturbs one layer at a time, so after warm-up a `latency()` call
//! only pays the analytical cost model for the layers whose configuration
//! actually changed (everything else is a hash lookup).  The cache is
//! invalidated automatically when a different model IR is evaluated and
//! explicitly via `invalidate_cache` (required after mutating `cost`).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use std::sync::OnceLock;

use super::cost::CostModel;
use super::shared::SharedCostCache;
use crate::compress::{DiscretePolicy, QuantMode};
use crate::model::{LayerKind, ModelIr};
use crate::obs;
use crate::util::rng::Pcg64;
use crate::util::stats::median;
use crate::util::Fnv1a;

/// Process-wide aggregates of the per-instance `cache_stats()` counters:
/// every simulator increments the same `cache="sim"` registry series, so
/// the `metrics` snapshot shows sweep-wide cache effectiveness while the
/// per-instance `Cell`s stay the exact per-object view the tests assert.
fn sim_cache_hits() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("latency_cache_hits_total", &[("cache", "sim")]))
}

fn sim_cache_misses() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("latency_cache_misses_total", &[("cache", "sim")]))
}

/// One latency measurement (seconds) with its raw samples.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Median-reduced latency estimate (seconds).
    pub latency_s: f64,
    /// The raw per-repetition samples behind the estimate.
    pub samples: Vec<f64>,
}

/// Memo key: one layer under one effective configuration.
type CostKey = (usize, usize, usize, QuantMode);

/// Cheap identity of the IR a cache was filled against: layer count plus an
/// order-sensitive FNV-1a hash over every layer's shape-defining fields, so
/// two structurally different IRs (even permutations with identical totals)
/// never share cached per-layer costs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct IrFingerprint {
    layers: usize,
    shape_hash: u64,
}

impl IrFingerprint {
    fn of(ir: &ModelIr) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x0100_0000_01b3);
        };
        for l in &ir.layers {
            mix(l.cin as u64);
            mix(l.cout as u64);
            mix(l.kernel as u64);
            mix(l.stride as u64);
            mix(l.in_spatial as u64);
            mix(l.out_spatial as u64);
            mix(l.depthwise as u64);
            mix(matches!(l.kind, LayerKind::Conv) as u64);
        }
        Self {
            layers: ir.layers.len(),
            shape_hash: h,
        }
    }
}

/// Analytical whole-model latency simulator (see the module docs).
#[derive(Clone, Debug)]
pub struct LatencySimulator {
    /// The analytical cost model.  Mutating it (or its target) requires
    /// `invalidate_cache` — memoized layer costs do not track it.
    pub cost: CostModel,
    /// Relative Gaussian measurement noise per repetition (sigma).
    pub noise_sigma: f64,
    /// Repetitions per measurement (median-reduced).
    pub repeats: usize,
    /// Seed of the per-`(ir, policy)` measurement-noise streams.
    seed: u64,
    /// Memoized `layer_cost(..).total()` per layer configuration.  Interior
    /// mutability keeps `latency` at `&self`.
    cache: RefCell<HashMap<CostKey, f64>>,
    /// Cross-worker shared memo (sweep orchestrator); consulted after the
    /// local cache, published to on every analytical evaluation.
    shared: Option<SharedCostCache>,
    cached_ir: Cell<IrFingerprint>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

impl LatencySimulator {
    /// A simulator over `cost` whose measurement noise is seeded by `seed`.
    pub fn new(cost: CostModel, seed: u64) -> Self {
        Self {
            cost,
            noise_sigma: 0.01,
            repeats: 5,
            seed,
            cache: RefCell::new(HashMap::new()),
            shared: None,
            cached_ir: Cell::new(IrFingerprint::default()),
            hits: Cell::new(0),
            misses: Cell::new(0),
        }
    }

    /// Attach a cross-worker cost cache (parallel sweeps): per-layer costs
    /// resolved by any simulator sharing the handle are reused here instead
    /// of re-running the analytical model.  Costs are pure functions of the
    /// configuration, so sharing cannot change any result — but only share
    /// between simulators with identical cost models (the shared key does
    /// not fingerprint the target; `search::LatencyFactory` guarantees
    /// this by construction).
    pub fn with_shared_cache(mut self, cache: SharedCostCache) -> Self {
        self.shared = Some(cache);
        self
    }

    /// Deterministic (noise-free) end-to-end latency of a compressed model.
    pub fn latency(&self, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        self.revalidate(ir);
        let mut cache = self.cache.borrow_mut();
        let mut total = 0.0;
        for l in &ir.layers {
            total += self.cached_layer_total(&mut cache, ir, policy, l.index);
        }
        total
    }

    /// Per-layer deterministic latency breakdown (profiling / Fig analysis).
    pub fn latency_per_layer(&self, ir: &ModelIr, policy: &DiscretePolicy) -> Vec<f64> {
        self.revalidate(ir);
        let mut cache = self.cache.borrow_mut();
        ir.layers
            .iter()
            .map(|l| self.cached_layer_total(&mut cache, ir, policy, l.index))
            .collect()
    }

    /// Noisy measurement: repeat + median, like the on-device harness.
    ///
    /// The noise stream is derived from `(seed, ir, policy)`, so the result
    /// is deterministic per configuration and independent of how many
    /// measurements happened before (call-order invariance — required for
    /// reproducible hybrid calibration).
    pub fn measure(&self, ir: &ModelIr, policy: &DiscretePolicy) -> Measurement {
        let base = self.latency(ir, policy);
        let mut rng = Pcg64::with_stream(self.seed, self.measurement_stream(ir, policy));
        let samples: Vec<f64> = (0..self.repeats)
            .map(|_| {
                let noise = 1.0 + self.noise_sigma * rng.normal();
                // measurement noise is one-sided-ish in practice (preemption
                // only ever slows you down); fold extreme negatives
                base * noise.max(1.0 - 2.0 * self.noise_sigma)
            })
            .collect();
        Measurement {
            latency_s: median(&samples),
            samples,
        }
    }

    /// Drop every *local* memoized layer cost.  Must be called after
    /// mutating `cost` (the cache cannot observe cost-model changes).  A
    /// shared sweep cache is deliberately left untouched — other workers'
    /// views of it stay valid; detach from it instead when the cost model
    /// diverges.
    pub fn invalidate_cache(&self) {
        self.cache.borrow_mut().clear();
        self.cached_ir.set(IrFingerprint::default());
    }

    /// (cache hits, cache misses) since construction / `reset_cache_stats`.
    /// Shared-cache hits count as hits (no analytical evaluation happened).
    /// This is the exact per-instance view; the same events also aggregate
    /// process-wide into the metrics registry as
    /// `latency_cache_hits_total{cache="sim"}` / `..misses_total{..}`.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Zero the hit/miss counters (between bench phases).
    pub fn reset_cache_stats(&self) {
        self.hits.set(0);
        self.misses.set(0);
    }

    fn cached_layer_total(
        &self,
        cache: &mut HashMap<CostKey, f64>,
        ir: &ModelIr,
        policy: &DiscretePolicy,
        i: usize,
    ) -> f64 {
        let l = &ir.layers[i];
        let cmp = &policy.layers[i];
        let eff_cin = policy.effective_cin(ir, i);
        let key = (i, eff_cin, cmp.kept_channels, cmp.quant);
        if let Some(&v) = cache.get(&key) {
            self.hits.set(self.hits.get() + 1);
            sim_cache_hits().inc();
            return v;
        }
        if let Some(shared) = &self.shared {
            let sk = self.shared_key(i, eff_cin, cmp.kept_channels, cmp.quant);
            if let Some(v) = shared.get(sk) {
                // another sweep worker already paid for this configuration
                self.hits.set(self.hits.get() + 1);
                sim_cache_hits().inc();
                cache.insert(key, v);
                return v;
            }
            self.misses.set(self.misses.get() + 1);
            sim_cache_misses().inc();
            let v = self.cost.layer_total(l, eff_cin, cmp.kept_channels, cmp.quant);
            cache.insert(key, v);
            shared.insert(sk, v);
            return v;
        }
        self.misses.set(self.misses.get() + 1);
        sim_cache_misses().inc();
        let v = self.cost.layer_total(l, eff_cin, cmp.kept_channels, cmp.quant);
        cache.insert(key, v);
        v
    }

    /// Key of one layer configuration in the cross-worker cache: unlike the
    /// local `CostKey`, it must also identify the IR (layer indices are only
    /// meaningful within one model).
    fn shared_key(&self, i: usize, eff_cin: usize, kept: usize, quant: QuantMode) -> u64 {
        let mut h = Fnv1a::seeded(self.cached_ir.get().shape_hash ^ 0x5c05_7001);
        h.mix(i as u64);
        h.mix(eff_cin as u64);
        h.mix(kept as u64);
        h.mix(quant.class_id());
        let (wb, ab) = quant.bits();
        h.mix(((wb as u64) << 32) | ab as u64);
        h.finish()
    }

    /// RNG stream id of one `(ir, policy)` measurement: FNV-1a over the IR
    /// shape fingerprint and every layer's effective configuration.  The
    /// mode class id keeps INT8 distinct from a hypothetical MIX(8/8).
    fn measurement_stream(&self, ir: &ModelIr, policy: &DiscretePolicy) -> u64 {
        let mut h = Fnv1a::seeded(IrFingerprint::of(ir).shape_hash ^ 0x1a7e);
        for cmp in &policy.layers {
            h.mix(cmp.kept_channels as u64);
            h.mix(cmp.quant.class_id());
            let (wb, ab) = cmp.quant.bits();
            h.mix(((wb as u64) << 32) | ab as u64);
        }
        h.finish()
    }

    /// Clear the cache when `ir` differs from the one it was filled against
    /// (layer indices are only meaningful within one IR).
    fn revalidate(&self, ir: &ModelIr) {
        let fp = IrFingerprint::of(ir);
        if self.cached_ir.get() != fp {
            self.cache.borrow_mut().clear();
            self.cached_ir.set(fp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::QuantMode;
    use crate::hw::HwTarget;
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn setup() -> (ModelIr, LatencySimulator) {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 7);
        (ir, sim)
    }

    /// The memoization-free reference: what `latency` computed before the
    /// cache existed.
    fn uncached_latency(cost: &CostModel, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        ir.layers
            .iter()
            .map(|l| {
                let cmp = &policy.layers[l.index];
                let eff_cin = policy.effective_cin(ir, l.index);
                cost.layer_total(l, eff_cin, cmp.kept_channels, cmp.quant)
            })
            .sum()
    }

    #[test]
    fn reference_latency_positive_and_deterministic() {
        let (ir, sim) = setup();
        let p = DiscretePolicy::reference(&ir);
        let a = sim.latency(&ir, &p);
        let b = sim.latency(&ir, &p);
        assert!(a > 0.0);
        assert_eq!(a, b);
        let per_layer = sim.latency_per_layer(&ir, &p);
        assert_eq!(per_layer.len(), ir.layers.len());
        assert!((per_layer.iter().sum::<f64>() - a).abs() < 1e-12);
    }

    #[test]
    fn compression_reduces_latency() {
        let (ir, sim) = setup();
        let reference = DiscretePolicy::reference(&ir);
        let base = sim.latency(&ir, &reference);

        let mut pruned = reference.clone();
        pruned.layers[1].kept_channels = 2;
        pruned.layers[3].kept_channels = 4;
        assert!(sim.latency(&ir, &pruned) < base);

        let mut quant = reference.clone();
        for l in &mut quant.layers {
            l.quant = QuantMode::Int8;
        }
        assert!(sim.latency(&ir, &quant) < base);
    }

    #[test]
    fn measurement_noise_bounded_and_seeded() {
        let (ir, _) = setup();
        let p = DiscretePolicy::reference(&ir);
        let sim1 = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 42);
        let sim2 = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 42);
        let base = sim1.latency(&ir, &p);
        let m1 = sim1.measure(&ir, &p);
        let m2 = sim2.measure(&ir, &p);
        assert_eq!(m1.latency_s, m2.latency_s, "seeded determinism");
        assert_eq!(m1.samples.len(), 5);
        assert!((m1.latency_s / base - 1.0).abs() < 0.1);
    }

    #[test]
    fn measurement_noise_is_call_order_independent() {
        let (ir, sim) = setup();
        let reference = DiscretePolicy::reference(&ir);
        let mut pruned = reference.clone();
        pruned.layers[1].kept_channels = 3;
        let mut quant = reference.clone();
        for l in &mut quant.layers {
            l.quant = QuantMode::Int8;
        }

        // measure in one order...
        let a1 = sim.measure(&ir, &reference);
        let b1 = sim.measure(&ir, &pruned);
        let c1 = sim.measure(&ir, &quant);
        // ...then the reverse order: per-policy results must be identical
        let c2 = sim.measure(&ir, &quant);
        let b2 = sim.measure(&ir, &pruned);
        let a2 = sim.measure(&ir, &reference);
        assert_eq!(a1.samples, a2.samples);
        assert_eq!(b1.samples, b2.samples);
        assert_eq!(c1.samples, c2.samples);

        // distinct policies still draw distinct noise streams
        assert_ne!(a1.samples, b1.samples);
        assert_ne!(b1.samples, c1.samples);
    }

    #[test]
    fn float_only_target_ignores_quant_modes() {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let sim = LatencySimulator::new(
            CostModel::new(HwTarget::cortex_a72().float_only()),
            3,
        );
        let reference = DiscretePolicy::reference(&ir);
        let mut quant = reference.clone();
        for l in &mut quant.layers {
            l.quant = QuantMode::Int8;
        }
        // on a float-only device quantization buys nothing
        let a = sim.latency(&ir, &reference);
        let b = sim.latency(&ir, &quant);
        assert_eq!(a, b);
    }

    #[test]
    fn memoized_latency_matches_uncached_after_mutations() {
        let (ir, sim) = setup();
        let mut rng = Pcg64::new(99);
        let mut policy = DiscretePolicy::reference(&ir);
        for step in 0..200 {
            // mutate one random layer per step, like the episode loop
            let i = rng.below(ir.layers.len());
            let l = &ir.layers[i];
            if l.prunable {
                policy.layers[i].kept_channels = 1 + rng.below(l.cout);
            }
            policy.layers[i].quant = match rng.below(3) {
                0 => QuantMode::Fp32,
                1 => QuantMode::Int8,
                _ => QuantMode::Mix {
                    w_bits: 1 + rng.below(6) as u8,
                    a_bits: 1 + rng.below(6) as u8,
                },
            };
            let cached = sim.latency(&ir, &policy);
            let fresh = uncached_latency(&sim.cost, &ir, &policy);
            assert_eq!(cached, fresh, "divergence at step {step}");
        }
        let per_layer = sim.latency_per_layer(&ir, &policy);
        assert_eq!(per_layer.len(), ir.layers.len());
    }

    #[test]
    fn single_layer_perturbation_costs_few_misses() {
        let (ir, sim) = setup();
        let mut policy = DiscretePolicy::reference(&ir);
        sim.latency(&ir, &policy); // warm the cache
        sim.reset_cache_stats();
        // change one prunable layer's width: only that layer and its
        // consumer (whose effective cin changed) can miss
        policy.layers[1].kept_channels = 2;
        sim.latency(&ir, &policy);
        let (hits, misses) = sim.cache_stats();
        assert!(misses <= 2, "expected <=2 misses, got {misses}");
        assert_eq!(hits + misses, ir.layers.len() as u64);
    }

    #[test]
    fn shared_cache_is_parity_preserving_and_reused() {
        let (ir, _) = setup();
        let shared = SharedCostCache::new();
        let a = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 7)
            .with_shared_cache(shared.clone());
        let b = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 7)
            .with_shared_cache(shared.clone());
        let plain = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 7);
        let p = DiscretePolicy::reference(&ir);

        let la = a.latency(&ir, &p);
        assert_eq!(la, plain.latency(&ir, &p), "sharing must not change values");
        assert!(!shared.is_empty());

        // the second simulator resolves every layer from the shared cache
        let lb = b.latency(&ir, &p);
        assert_eq!(la, lb);
        let (hits, misses) = b.cache_stats();
        assert_eq!(misses, 0, "all layer costs must come from the shared cache");
        assert_eq!(hits, ir.layers.len() as u64);
    }

    #[test]
    fn mobilenet_depthwise_layers_carry_nontrivial_costs() {
        let ir = ModelIr::from_meta(&crate::model::zoo::meta("mobilenetv2s").unwrap()).unwrap();
        let sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 7);
        let p = DiscretePolicy::reference(&ir);
        let per_layer = sim.latency_per_layer(&ir, &p);
        let total: f64 = per_layer.iter().sum();
        assert!(total > 0.0);
        for l in ir.layers.iter().filter(|l| l.depthwise) {
            let t = per_layer[l.index];
            assert!(t > 0.0, "{}", l.name);
            // more than MAC-proportionality would grant: depthwise MACs are
            // a tiny fraction of the model, but launch/elementwise/memory
            // terms keep the layers visible in the profile
            let mac_share = l.macs() as f64 / ir.total_macs() as f64;
            assert!(
                t / total > mac_share,
                "{}: latency share {:.4} vs MAC share {:.4}",
                l.name,
                t / total,
                mac_share
            );
        }
        // the memoized path agrees with a fresh evaluation (depthwise keys
        // cache correctly alongside dense ones)
        let again = sim.latency(&ir, &p);
        assert_eq!(again, total);
    }

    #[test]
    fn invalidate_clears_and_stays_correct() {
        let (ir, sim) = setup();
        let p = DiscretePolicy::reference(&ir);
        let a = sim.latency(&ir, &p);
        sim.invalidate_cache();
        sim.reset_cache_stats();
        let b = sim.latency(&ir, &p);
        let (hits, misses) = sim.cache_stats();
        assert_eq!(a, b);
        assert_eq!(hits, 0, "cache was not actually cleared");
        assert_eq!(misses, ir.layers.len() as u64);
    }
}
