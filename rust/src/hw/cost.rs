//! Analytical per-layer cost model.
//!
//! Latency per operator = compute roofline term x cache-efficiency factor
//! + data-movement overheads (quantize / requantize / bit-packing)
//! + elementwise epilogue (BN, ReLU, residual) + fixed launch overhead.
//!
//! The cache-efficiency factor implements the "cache boundness of ML
//! operators on ARM" observation (Klein et al. 2021) that makes measured
//! latency deviate from MAC/BOP proportionality — the paper's core argument
//! for direct hardware feedback.

use super::constraints::mix_supported;
use super::target::HwTarget;
use crate::compress::QuantMode;
use crate::model::Layer;
#[cfg(test)]
use crate::model::LayerKind;

/// Cost breakdown of one layer under one configuration (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerCost {
    /// Roofline compute term (GEMM / bit-serial).
    pub compute: f64,
    /// Dynamic quantize/requantize data movement.
    pub quant_overhead: f64,
    /// Bit-serial activation packing.
    pub pack_overhead: f64,
    /// Elementwise epilogue (BN, ReLU, residual add).
    pub elementwise: f64,
    /// Fixed per-operator launch overhead.
    pub launch: f64,
}

impl LayerCost {
    /// Sum of all terms (seconds).
    pub fn total(&self) -> f64 {
        self.compute + self.quant_overhead + self.pack_overhead + self.elementwise + self.launch
    }
}

/// Sustained-efficiency derating of depthwise convolutions: one k x k
/// filter per channel means no cross-channel weight reuse, so the GEMM-style
/// multi-accumulator blocking never amortizes — depthwise operators run
/// memory-bound at a fraction of the dense roofline (the classic MobileNet
/// observation: great MAC counts, mediocre MAC rates).  Applied on top of
/// the cache/shape efficiency factor in every compute arm.
const DW_EFFICIENCY: f64 = 0.35;

/// The analytical cost model for one hardware target.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// The device being modeled.
    pub target: HwTarget,
}

impl CostModel {
    /// A cost model for `target`.
    pub fn new(target: HwTarget) -> Self {
        Self { target }
    }

    /// Sustained-efficiency factor in (0, 0.9]: fraction of the roofline a
    /// GEMM-lowered operator achieves given its working set and shape.
    ///
    /// Piecewise-smooth in the working-set size: ~0.85 in-L1, sliding to
    /// ~0.60 in-L2, down to ~0.38 when streaming from DRAM; small spatial
    /// extents and narrow channel counts under-fill the SIMD lanes.
    fn efficiency(&self, working_set: f64, out_spatial: usize, cout: usize) -> f64 {
        let l1 = self.target.l1_bytes as f64;
        let l2 = self.target.l2_bytes as f64;
        let cache = if working_set <= l1 {
            0.85
        } else if working_set <= l2 {
            // interpolate 0.85 -> 0.60 across L2
            let t = ((working_set - l1) / (l2 - l1)).clamp(0.0, 1.0);
            0.85 - 0.25 * t
        } else {
            // interpolate 0.60 -> 0.38 as the set grows past L2 (up to 8x)
            let t = ((working_set / l2).ln() / 8f64.ln()).clamp(0.0, 1.0);
            0.60 - 0.22 * t
        };
        let spatial = if out_spatial >= 8 {
            1.0
        } else if out_spatial >= 4 {
            0.8
        } else {
            0.55
        };
        let lanes = if cout >= 16 {
            1.0
        } else if cout >= 8 {
            0.85
        } else {
            0.6
        };
        (cache * spatial * lanes).max(0.05)
    }

    /// Bytes touched by the GEMM-lowered operator at `bytes_per_elem`.
    fn working_set(&self, l: &Layer, cin: usize, cout: usize, bytes_per_elem: f64) -> f64 {
        let weights = l.params_at(cin, cout) as f64 * bytes_per_elem;
        let acts_in = l.in_elems(cin) as f64 * bytes_per_elem;
        let acts_out = l.out_elems(cout) as f64 * bytes_per_elem;
        weights + acts_in + acts_out
    }

    /// `layer_cost(..).total()` — the scalar the latency simulator memoizes.
    pub fn layer_total(
        &self,
        l: &Layer,
        eff_cin: usize,
        eff_cout: usize,
        quant: QuantMode,
    ) -> f64 {
        self.layer_cost(l, eff_cin, eff_cout, quant).total()
    }

    /// Latency of one layer (batch 1) under effective channel counts and a
    /// quantization mode.  Falls back internally (MIX->INT8->FP32) when the
    /// target or the layer configuration does not support the mode — the
    /// same fallback the policy mapping applies, so probing unsupported
    /// configurations is safe and matches deployment.
    ///
    /// Purity contract: the result is a pure function of
    /// `(layer, eff_cin, eff_cout, quant)` and the (immutable-by-convention)
    /// target parameters — this is what makes the simulator-level
    /// memoization sound.  Mutating `self.target` requires
    /// `LatencySimulator::invalidate_cache` on any simulator wrapping this
    /// model.
    pub fn layer_cost(
        &self,
        l: &Layer,
        eff_cin: usize,
        eff_cout: usize,
        quant: QuantMode,
    ) -> LayerCost {
        let t = &self.target;
        let quant = self.effective_mode(l, eff_cin, eff_cout, quant);
        let macs = l.macs_at(eff_cin, eff_cout) as f64;
        let in_e = l.in_elems(eff_cin) as f64;
        let out_e = l.out_elems(eff_cout) as f64;
        // depthwise operators sustain a fraction of the dense roofline
        // (no cross-channel weight reuse) — see `DW_EFFICIENCY`
        let dw = if l.depthwise { DW_EFFICIENCY } else { 1.0 };

        let mut c = LayerCost {
            launch: t.layer_overhead_s,
            // BN scale+shift + ReLU + (residual share): ~3 elementwise passes
            elementwise: 3.0 * out_e / t.elemwise_per_sec,
            ..Default::default()
        };

        match quant {
            QuantMode::Fp32 => {
                let ws = self.working_set(l, eff_cin, eff_cout, 4.0);
                let eff = dw * self.efficiency(ws, l.out_spatial, eff_cout);
                c.compute = macs / (t.f32_peak() * eff);
                // DRAM streaming term when the working set spills L2
                if ws > t.l2_bytes as f64 {
                    c.compute += (ws - t.l2_bytes as f64) / t.mem_bw;
                }
            }
            QuantMode::Int8 => {
                let ws = self.working_set(l, eff_cin, eff_cout, 1.0);
                let eff = dw * self.efficiency(ws, l.out_spatial, eff_cout);
                c.compute = macs / (t.int8_peak() * eff);
                // dynamic-range quantize of inputs + requantize of outputs
                c.quant_overhead = (2.0 * in_e + 2.0 * out_e) / t.elemwise_per_sec;
                if ws > t.l2_bytes as f64 {
                    c.compute += (ws - t.l2_bytes as f64) / t.mem_bw;
                }
            }
            QuantMode::Mix { w_bits, a_bits } => {
                // bit-serial popcount GEMM: one binary GEMM per bit-plane
                // pair.  Unreachable for depthwise layers — the operator
                // constraints exclude them and `effective_mode` falls back
                // to Int8 — so assert the invariant rather than letting the
                // `dw` derating silently absorb a future fallback change
                // (the factor still applies in release builds as a
                // belt-and-braces derating should this ever be reached).
                debug_assert!(
                    !l.depthwise,
                    "{}: depthwise layer reached the bit-serial cost arm — \
                     effective_mode should have folded Mix onto Int8",
                    l.name
                );
                let wb = w_bits as f64;
                let ab = a_bits as f64;
                let ws = self.working_set(l, eff_cin, eff_cout, (wb + ab) / 16.0);
                let eff = dw * self.efficiency(ws, l.out_spatial, eff_cout);
                c.compute = macs * wb * ab / (t.binary_macs_per_sec * eff);
                // activation bit-plane packing (weights packed offline)
                c.pack_overhead = ab * in_e / t.pack_per_sec;
                // dequant epilogue
                c.quant_overhead = 2.0 * out_e / t.elemwise_per_sec;
            }
        }
        c
    }

    /// The mode the deployed runtime would actually run (support fallback).
    pub fn effective_mode(
        &self,
        l: &Layer,
        eff_cin: usize,
        eff_cout: usize,
        quant: QuantMode,
    ) -> QuantMode {
        match quant {
            QuantMode::Mix { .. } => {
                if self.target.supports_bitserial && mix_supported(l, eff_cin, eff_cout) {
                    quant
                } else if self.target.supports_int8 {
                    QuantMode::Int8
                } else {
                    QuantMode::Fp32
                }
            }
            QuantMode::Int8 => {
                if self.target.supports_int8 {
                    QuantMode::Int8
                } else {
                    QuantMode::Fp32
                }
            }
            QuantMode::Fp32 => QuantMode::Fp32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;

    /// Conv helper, parameterized over the depthwise flag (the previous
    /// version hardcoded `depthwise: false`, so no cost test could ever
    /// exercise the depthwise path).
    fn conv_dw(cin: usize, cout: usize, k: usize, sp: usize, depthwise: bool) -> Layer {
        Layer {
            index: 0,
            name: "t".into(),
            kind: LayerKind::Conv,
            cin,
            cout,
            kernel: k,
            stride: 1,
            in_spatial: sp,
            out_spatial: sp,
            prunable: true,
            group: -1,
            depthwise,
        }
    }

    fn conv(cin: usize, cout: usize, k: usize, sp: usize) -> Layer {
        conv_dw(cin, cout, k, sp, false)
    }

    fn model() -> CostModel {
        CostModel::new(HwTarget::cortex_a72())
    }

    #[test]
    fn int8_beats_fp32_on_big_layers() {
        let m = model();
        let l = conv(128, 128, 3, 16);
        let f = m.layer_cost(&l, 128, 128, QuantMode::Fp32).total();
        let q = m.layer_cost(&l, 128, 128, QuantMode::Int8).total();
        assert!(q < f, "int8 {q} vs fp32 {f}");
        assert!(q > f / 4.0, "quantize overhead must not vanish");
    }

    #[test]
    fn bitserial_crossover_near_6_bits() {
        // paper §Exploration Range: >6 bits is slower than INT8; low bit
        // widths are substantially faster.
        let m = model();
        let l = conv(128, 128, 3, 16);
        let int8 = m.layer_cost(&l, 128, 128, QuantMode::Int8).total();
        let mix = |b: u8| {
            m.layer_cost(
                &l,
                128,
                128,
                QuantMode::Mix {
                    w_bits: b,
                    a_bits: b,
                },
            )
            .total()
        };
        assert!(mix(7) > int8, "7x7 {} should exceed int8 {}", mix(7), int8);
        assert!(mix(4) < int8);
        assert!(mix(2) < 0.6 * int8, "2x2 {} vs int8 {}", mix(2), int8);
        assert!(mix(1) < mix(2));
        // monotone in bit width
        for b in 2..=7u8 {
            assert!(mix(b) >= mix(b - 1));
        }
    }

    #[test]
    fn latency_not_proportional_to_macs() {
        // Two layers with identical MACs but different shapes must cost
        // differently (cache boundness) — the paper's direct-metric argument.
        let m = model();
        let a = conv(64, 64, 3, 32); // big spatial, fits worse
        let b = conv(256, 256, 3, 8); // same MACs: 64*64*9*1024 == 256*256*9*64
        assert_eq!(a.macs(), b.macs());
        let ca = m.layer_cost(&a, 64, 64, QuantMode::Fp32).total();
        let cb = m.layer_cost(&b, 256, 256, QuantMode::Fp32).total();
        let ratio = ca / cb;
        assert!(
            (ratio - 1.0).abs() > 0.10,
            "expected >10% divergence, got ratio {ratio}"
        );
    }

    #[test]
    fn pruning_reduces_cost_superlinearly_when_cache_relief() {
        let m = model();
        let l = conv(256, 256, 3, 8);
        let full = m.layer_cost(&l, 256, 256, QuantMode::Fp32).total();
        let half = m.layer_cost(&l, 256, 128, QuantMode::Fp32).total();
        assert!(half < full);
        assert!(half > 0.25 * full);
    }

    #[test]
    fn mode_fallback_chain() {
        let m = model();
        let first = conv(3, 32, 3, 32); // cin=3: MIX unsupported
        let mode = m.effective_mode(
            &first,
            3,
            32,
            QuantMode::Mix {
                w_bits: 4,
                a_bits: 4,
            },
        );
        assert_eq!(mode, QuantMode::Int8);

        let float_only = CostModel::new(HwTarget::cortex_a72().float_only());
        let mode = float_only.effective_mode(&first, 3, 32, QuantMode::Int8);
        assert_eq!(mode, QuantMode::Fp32);
    }

    #[test]
    fn linear_layer_costs() {
        let m = model();
        let fc = Layer {
            index: 0,
            name: "fc".into(),
            kind: LayerKind::Linear,
            cin: 256,
            cout: 10,
            kernel: 1,
            stride: 1,
            in_spatial: 1,
            out_spatial: 1,
            prunable: false,
            group: -1,
            depthwise: false,
        };
        let c = m.layer_cost(&fc, 256, 10, QuantMode::Fp32);
        assert!(c.total() > 0.0);
        assert!(c.launch > 0.0);
    }

    #[test]
    fn depthwise_cheaper_than_dense_but_dearer_per_mac() {
        let m = model();
        let dense = conv(128, 128, 3, 16);
        let dw = conv_dw(128, 128, 3, 16, true);
        // 128x fewer MACs...
        assert_eq!(dense.macs(), 128 * dw.macs());
        let dense_cost = m.layer_cost(&dense, 128, 128, QuantMode::Fp32).total();
        let dw_cost = m.layer_cost(&dw, 128, 128, QuantMode::Fp32).total();
        // ...buys less than 128x the latency: depthwise is memory-bound
        assert!(dw_cost < dense_cost, "dw {dw_cost} vs dense {dense_cost}");
        assert!(
            dw_cost > 2.0 * dense_cost / 128.0,
            "depthwise must not be costed MAC-proportionally: {dw_cost} vs {}",
            dense_cost / 128.0
        );
        // the derating reaches the compute term itself
        let dw_as_dense_macs = m.layer_cost(&dw, 128, 128, QuantMode::Fp32).compute;
        let mut undw = dw.clone();
        undw.depthwise = false;
        let per_mac_dense =
            m.layer_cost(&undw, 128, 128, QuantMode::Fp32).compute / undw.macs() as f64;
        assert!(dw_as_dense_macs / dw.macs() as f64 > per_mac_dense);
    }

    #[test]
    fn depthwise_never_runs_bitserial() {
        let m = model();
        let dw = conv_dw(128, 128, 3, 16, true);
        // channels satisfy the %32/%8 rules, but depthwise is excluded
        let mode = m.effective_mode(&dw, 128, 128, QuantMode::Mix { w_bits: 4, a_bits: 4 });
        assert_eq!(mode, QuantMode::Int8);
        // and the costed MIX request therefore equals the INT8 cost
        let mix = m
            .layer_cost(&dw, 128, 128, QuantMode::Mix { w_bits: 4, a_bits: 4 })
            .total();
        let int8 = m.layer_cost(&dw, 128, 128, QuantMode::Int8).total();
        assert_eq!(mix, int8);
    }

    #[test]
    fn cost_components_nonnegative() {
        let m = model();
        let l = conv(32, 64, 3, 16);
        for q in [
            QuantMode::Fp32,
            QuantMode::Int8,
            QuantMode::Mix {
                w_bits: 3,
                a_bits: 5,
            },
        ] {
            let c = m.layer_cost(&l, 32, 64, q);
            assert!(c.compute >= 0.0 && c.quant_overhead >= 0.0);
            assert!(c.pack_overhead >= 0.0 && c.elementwise >= 0.0);
            assert!(c.total().is_finite());
        }
    }
}
