//! The pluggable latency backend of the search loop.
//!
//! `LatencyProvider` abstracts "how long does this compressed model take"
//! so `search::run_search` can consume either the analytical simulator, the
//! measured-kernel profiler, or the hybrid of the two — selected with
//! `--latency sim|measured|hybrid` on the CLI.
//!
//! The hybrid provider implements the practical middle ground: measuring
//! every configuration the agent probes is expensive, so it measures a
//! small calibration set once, fits per-mode scale coefficients to the
//! analytical `CostModel` by least squares on the relative residuals
//! (minimizing `sum_i (1 - alpha * sim_i / meas_i)^2`, the estimator that
//! directly reduces mean relative error), and afterwards answers from the
//! measured cache when a configuration is known and from the *calibrated*
//! simulator when it is not.

use anyhow::Result;

use super::profiler::MeasuredProfiler;
use super::sim::{LatencySimulator, Measurement};
use crate::compress::{DiscretePolicy, QuantMode};
use crate::model::{Layer, ModelIr};

/// Latency backend of a policy search.
pub trait LatencyProvider {
    /// Deterministic central latency estimate (seconds) — used for the
    /// reference/base latency a search normalizes against.
    fn latency(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> f64;

    /// The per-episode measurement the reward consumes (may carry noise).
    fn measure(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> Measurement;

    /// Short backend label for logs and result records.
    fn backend(&self) -> &'static str;

    /// (hits, misses/measured) of whatever cache the provider keeps.
    fn cache_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Flush any on-disk state (profile caches).  No-op by default.
    fn persist(&mut self) -> Result<()> {
        Ok(())
    }
}

impl LatencyProvider for LatencySimulator {
    fn latency(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        LatencySimulator::latency(self, ir, policy)
    }

    fn measure(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> Measurement {
        LatencySimulator::measure(self, ir, policy)
    }

    fn backend(&self) -> &'static str {
        "sim"
    }

    fn cache_stats(&self) -> (u64, u64) {
        LatencySimulator::cache_stats(self)
    }
}

impl LatencyProvider for MeasuredProfiler {
    fn latency(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        self.model_latency(ir, policy)
    }

    fn measure(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> Measurement {
        // steady-state trimmed medians are already noise-rejected; the
        // measurement *is* the estimate
        let latency_s = self.model_latency(ir, policy);
        Measurement {
            latency_s,
            samples: vec![latency_s],
        }
    }

    fn backend(&self) -> &'static str {
        // provenance: record when any value in play is an analytical
        // fallback rather than a real measurement
        if self.stats().degraded > 0 {
            "measured+analytical-fallback"
        } else {
            "measured"
        }
    }

    fn cache_stats(&self) -> (u64, u64) {
        let s = self.stats();
        (s.hits, s.measured)
    }

    fn persist(&mut self) -> Result<()> {
        self.save().map(|_| ())
    }
}

/// Which latency backend a session should construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LatencyKind {
    /// Analytical cost-model simulator (fast, noise-injected).
    Sim,
    /// Real kernel measurements with the profile cache.
    Measured,
    /// Measured where cached, least-squares-calibrated simulator elsewhere.
    Hybrid,
}

/// Parses the CLI labels `sim`/`measured`/`hybrid` (with the aliases
/// `simulator`/`profiler`) — the inverse of the `Display` labels.
impl std::str::FromStr for LatencyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sim" | "simulator" => Ok(Self::Sim),
            "measured" | "profiler" => Ok(Self::Measured),
            "hybrid" => Ok(Self::Hybrid),
            other => anyhow::bail!("unknown latency backend '{other}' (sim|measured|hybrid)"),
        }
    }
}

/// Stable lowercase label (CLI, records, logs); honors format padding.
impl std::fmt::Display for LatencyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            Self::Sim => "sim",
            Self::Measured => "measured",
            Self::Hybrid => "hybrid",
        })
    }
}

/// Mode classes the hybrid calibration fits one coefficient for (the
/// `QuantMode::class_id` discriminants: FP32 / INT8 / MIX).
const CLASSES: usize = QuantMode::CLASSES;

fn mode_class(mode: QuantMode) -> usize {
    mode.class_id() as usize
}

/// Measured-where-known, calibrated-analytical elsewhere.
#[derive(Debug)]
pub struct HybridProvider {
    /// The measured half (answers for known configurations).
    pub profiler: MeasuredProfiler,
    /// The analytical half (calibrated fallback).
    pub sim: LatencySimulator,
    /// Per-mode-class multipliers mapping analytical seconds onto measured
    /// seconds (identity until `calibrate` runs).
    scales: [f64; CLASSES],
    calibrated: bool,
}

impl HybridProvider {
    /// An uncalibrated hybrid of `profiler` and `sim` (scales = 1.0).
    pub fn new(profiler: MeasuredProfiler, sim: LatencySimulator) -> Self {
        Self {
            profiler,
            sim,
            scales: [1.0; CLASSES],
            calibrated: false,
        }
    }

    /// Whether `calibrate` has run.
    pub fn is_calibrated(&self) -> bool {
        self.calibrated
    }

    /// The fitted per-class coefficients `[fp32, int8, mix]`.
    pub fn scales(&self) -> [f64; CLASSES] {
        self.scales
    }

    /// Calibrated analytical latency of one layer configuration — what the
    /// hybrid answers when the configuration was never measured.
    pub fn calibrated_layer_total(
        &self,
        l: &Layer,
        eff_cin: usize,
        kept: usize,
        quant: QuantMode,
    ) -> f64 {
        let mode = self.sim.cost.effective_mode(l, eff_cin, kept, quant);
        self.scales[mode_class(mode)] * self.sim.cost.layer_total(l, eff_cin, kept, quant)
    }

    /// Fit the per-class coefficients against measured samples of every
    /// distinct layer configuration in `policies` (measuring each through
    /// the profiler, so the samples also seed the measured cache).
    ///
    /// Least squares on relative residuals: with `r_i = sim_i / meas_i`,
    /// `alpha = sum r_i / sum r_i^2` minimizes
    /// `sum_i (1 - alpha * r_i)^2` — the squared relative error of the
    /// calibrated prediction.
    pub fn calibrate(&mut self, ir: &ModelIr, policies: &[DiscretePolicy]) {
        let mut seen = std::collections::HashSet::new();
        let mut num = [0.0f64; CLASSES];
        let mut den = [0.0f64; CLASSES];
        for policy in policies {
            for l in &ir.layers {
                let cmp = &policy.layers[l.index];
                let eff_cin = policy.effective_cin(ir, l.index);
                let mode = self
                    .sim
                    .cost
                    .effective_mode(l, eff_cin, cmp.kept_channels, cmp.quant);
                if !seen.insert(super::profiler::config_key(l, eff_cin, cmp.kept_channels, mode)) {
                    continue;
                }
                let meas = self
                    .profiler
                    .layer_latency(l, eff_cin, cmp.kept_channels, cmp.quant);
                let sim_t = self
                    .sim
                    .cost
                    .layer_total(l, eff_cin, cmp.kept_channels, cmp.quant);
                if meas > 0.0 && sim_t > 0.0 {
                    let r = sim_t / meas;
                    let c = mode_class(mode);
                    num[c] += r;
                    den[c] += r * r;
                }
            }
        }
        for c in 0..CLASSES {
            if den[c] > 0.0 {
                self.scales[c] = num[c] / den[c];
            }
        }
        self.calibrated = true;
        log::info!(
            "hybrid calibration: scales fp32={:.3e} int8={:.3e} mix={:.3e}",
            self.scales[0],
            self.scales[1],
            self.scales[2]
        );
    }

    /// Calibrate on a small default probe set spanning the mode classes and
    /// a pruned shape per prunable layer.
    pub fn calibrate_default(&mut self, ir: &ModelIr) {
        let reference = DiscretePolicy::reference(ir);
        let mut int8 = reference.clone();
        for l in &mut int8.layers {
            l.quant = QuantMode::Int8;
        }
        let mut mix = reference.clone();
        for l in &mut mix.layers {
            l.quant = QuantMode::Mix { w_bits: 4, a_bits: 4 };
        }
        let mut pruned = reference.clone();
        for l in ir.layers.iter().filter(|l| l.prunable) {
            pruned.layers[l.index].kept_channels = (l.cout / 2).max(1);
        }
        let mut pruned_int8 = pruned.clone();
        for l in &mut pruned_int8.layers {
            l.quant = QuantMode::Int8;
        }
        self.calibrate(ir, &[reference, int8, mix, pruned, pruned_int8]);
    }

    fn layer_latency(&mut self, ir: &ModelIr, policy: &DiscretePolicy, i: usize) -> f64 {
        let l = &ir.layers[i];
        let cmp = &policy.layers[i];
        let eff_cin = policy.effective_cin(ir, i);
        if let Some(measured) = self.profiler.lookup(l, eff_cin, cmp.kept_channels, cmp.quant) {
            measured
        } else {
            self.calibrated_layer_total(l, eff_cin, cmp.kept_channels, cmp.quant)
        }
    }
}

impl LatencyProvider for HybridProvider {
    fn latency(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        (0..ir.layers.len())
            .map(|i| self.layer_latency(ir, policy, i))
            .sum()
    }

    fn measure(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> Measurement {
        let latency_s = LatencyProvider::latency(self, ir, policy);
        Measurement {
            latency_s,
            samples: vec![latency_s],
        }
    }

    fn backend(&self) -> &'static str {
        if self.profiler.stats().degraded > 0 {
            "hybrid+analytical-fallback"
        } else {
            "hybrid"
        }
    }

    fn cache_stats(&self) -> (u64, u64) {
        let s = self.profiler.stats();
        (s.hits, s.measured)
    }

    fn persist(&mut self) -> Result<()> {
        self.profiler.save().map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{CostModel, HwTarget, ProfilerConfig};
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn ir() -> ModelIr {
        ModelIr::from_meta(&tiny_meta()).unwrap()
    }

    fn hybrid() -> HybridProvider {
        HybridProvider::new(
            MeasuredProfiler::new(HwTarget::cortex_a72(), "tiny", ProfilerConfig::fast()),
            LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 9),
        )
    }

    #[test]
    fn latency_kind_parse_display_roundtrip() {
        assert_eq!("sim".parse::<LatencyKind>().unwrap(), LatencyKind::Sim);
        assert_eq!("measured".parse::<LatencyKind>().unwrap(), LatencyKind::Measured);
        assert_eq!("hybrid".parse::<LatencyKind>().unwrap(), LatencyKind::Hybrid);
        assert!("nope".parse::<LatencyKind>().is_err());
        for kind in [LatencyKind::Sim, LatencyKind::Measured, LatencyKind::Hybrid] {
            assert_eq!(kind.to_string().parse::<LatencyKind>().unwrap(), kind);
        }
    }

    #[test]
    fn simulator_satisfies_provider() {
        let ir = ir();
        let mut sim = LatencySimulator::new(CostModel::new(HwTarget::cortex_a72()), 3);
        let p = DiscretePolicy::reference(&ir);
        let provider: &mut dyn LatencyProvider = &mut sim;
        let base = provider.latency(&ir, &p);
        assert!(base > 0.0);
        let m = provider.measure(&ir, &p);
        assert!((m.latency_s / base - 1.0).abs() < 0.1);
        assert_eq!(provider.backend(), "sim");
    }

    #[test]
    fn hybrid_uses_measured_when_cached_and_calibrated_sim_otherwise() {
        let ir = ir();
        let mut h = hybrid();
        let reference = DiscretePolicy::reference(&ir);
        h.calibrate(&ir, &[reference.clone()]);
        assert!(h.is_calibrated());

        // every reference config was measured during calibration
        let measured_total: f64 = (0..ir.layers.len())
            .map(|i| {
                let l = &ir.layers[i];
                h.profiler
                    .lookup(l, reference.effective_cin(&ir, i), l.cout, QuantMode::Fp32)
                    .expect("calibration must seed the measured cache")
            })
            .sum();
        assert_eq!(LatencyProvider::latency(&mut h, &ir, &reference), measured_total);

        // an unmeasured policy falls back to the calibrated simulator
        let mut int8 = reference.clone();
        for l in &mut int8.layers {
            l.quant = QuantMode::Int8;
        }
        let before = h.profiler.stats().measured;
        let lat = LatencyProvider::latency(&mut h, &ir, &int8);
        assert_eq!(
            h.profiler.stats().measured,
            before,
            "hybrid latency must never trigger new measurements"
        );
        let expected: f64 = ir
            .layers
            .iter()
            .map(|l| {
                h.calibrated_layer_total(
                    l,
                    int8.effective_cin(&ir, l.index),
                    l.cout,
                    QuantMode::Int8,
                )
            })
            .sum();
        assert_eq!(lat, expected);
    }

    #[test]
    fn calibration_scales_are_positive_and_finite() {
        let ir = ir();
        let mut h = hybrid();
        h.calibrate_default(&ir);
        for s in h.scales() {
            assert!(s.is_finite() && s > 0.0, "scale {s}");
        }
    }
}
