//! Target-device description.  The default models the paper's Raspberry Pi
//! 4B (quad Cortex-A72 @ 1.5 GHz): NEON 128-bit SIMD, 32 KiB L1d per core,
//! 1 MiB shared L2, LPDDR4.  All knobs are plain fields so ablations and
//! tests can fabricate alternative devices (e.g. one without quantization
//! support — the paper's motivation for hardware-specific search).

/// All modeled parameters of one target device.
#[derive(Clone, Debug)]
pub struct HwTarget {
    /// Human-readable device name (also the cache directory name).
    pub name: String,
    /// Core count.
    pub cores: usize,
    /// Clock frequency (Hz).
    pub freq_hz: f64,
    /// f32 MACs per cycle per core (NEON 128-bit FMA).
    pub f32_macs_per_cycle: f64,
    /// Throughput multiplier of the int8 GEMM kernels over f32.
    pub int8_speedup: f64,
    /// Binary (1-bit x 1-bit) MACs per second, all cores — the popcount
    /// GEMM roofline of the TVM bit-serial operators (Cowan et al. 2020).
    pub binary_macs_per_sec: f64,
    /// Elementwise throughput (elems/s, all cores) for quantize/requantize,
    /// BN-scale, ReLU, residual adds.
    pub elemwise_per_sec: f64,
    /// Activation bit-packing throughput for bit-serial (elems/s per plane).
    pub pack_per_sec: f64,
    /// Sustained memory bandwidth (bytes/s) for cache-miss traffic.
    pub mem_bw: f64,
    /// L1 data cache per core (bytes).
    pub l1_bytes: usize,
    /// Shared L2 cache (bytes).
    pub l2_bytes: usize,
    /// Fixed per-operator launch overhead (s) — TVM op call + scheduling.
    pub layer_overhead_s: f64,
    /// Whether the deployed runtime ships quantized kernels at all
    /// (hardware-specific search motivation: some targets do not).
    pub supports_int8: bool,
    /// Whether the runtime ships the TVM-style bit-serial operators.
    pub supports_bitserial: bool,
}

impl HwTarget {
    /// Raspberry Pi 4B / ARM Cortex-A72 (the paper's testbed).
    ///
    /// Constant provenance (order-of-magnitude, calibrated to the paper's
    /// qualitative claims rather than absolute numbers):
    /// * 4 cores x 1.5 GHz x 4 f32 MACs/cycle  => 24 GMAC/s peak;
    ///   TVM fp32 conv sustains a cache-dependent 40-85 % of that.
    /// * int8 dot kernels: ~2.8x f32 (SDOT-less A72 gets less than A76).
    /// * bit-serial popcount GEMM: ~83x f32 MAC rate per *binary* op —
    ///   calibrated so MIX 6x6 lands slightly above INT8 (paper found >6
    ///   bits slower than INT8) and MIX 2x2 roughly 3-4x under it.
    pub fn cortex_a72() -> Self {
        Self {
            name: "raspberry-pi-4b/cortex-a72".into(),
            cores: 4,
            freq_hz: 1.5e9,
            f32_macs_per_cycle: 4.0,
            int8_speedup: 2.8,
            binary_macs_per_sec: 2.8e12,
            elemwise_per_sec: 6.0e9,
            pack_per_sec: 2.5e9,
            mem_bw: 4.0e9,
            l1_bytes: 32 * 1024,
            l2_bytes: 1024 * 1024,
            layer_overhead_s: 18e-6,
            supports_int8: true,
            supports_bitserial: true,
        }
    }

    /// A float-only device (no quantized kernels): used by ablations to show
    /// the search adapting to hardware capabilities.
    pub fn float_only(mut self) -> Self {
        self.supports_int8 = false;
        self.supports_bitserial = false;
        self.name = format!("{}+float-only", self.name);
        self
    }

    /// Peak f32 MAC throughput (MACs/s, all cores).
    pub fn f32_peak(&self) -> f64 {
        self.cores as f64 * self.freq_hz * self.f32_macs_per_cycle
    }

    /// Peak int8 MAC throughput (MACs/s, all cores).
    pub fn int8_peak(&self) -> f64 {
        self.f32_peak() * self.int8_speedup
    }

    /// Hex form of the profiler's capability fingerprint — the same value
    /// that invalidates profile caches guards artifact manifests against
    /// replaying a latency claim on a differently-configured target.
    ///
    /// Host-side kernel properties (dispatch ISA, autotuned tile config)
    /// are deliberately *not* part of the fingerprint: they never change
    /// what the kernels compute, and folding them in would make `.galen`
    /// artifacts differ byte-for-byte across `GALEN_SIMD` modes.  The
    /// profile-cache manifest records the host ISA separately and rejects
    /// caches measured under a different kernel backend.
    pub fn fingerprint_hex(&self) -> String {
        format!("{:016x}", super::profiler::target_fingerprint(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a72_peaks() {
        let t = HwTarget::cortex_a72();
        assert_eq!(t.f32_peak(), 24e9);
        assert!(t.int8_peak() > t.f32_peak());
        assert!(t.supports_int8 && t.supports_bitserial);
    }

    #[test]
    fn float_only_strips_quant() {
        let t = HwTarget::cortex_a72().float_only();
        assert!(!t.supports_int8 && !t.supports_bitserial);
        assert!(t.name.contains("float-only"));
    }

    #[test]
    fn fingerprint_hex_tracks_capabilities() {
        let a = HwTarget::cortex_a72();
        let fp = a.fingerprint_hex();
        assert_eq!(fp.len(), 16);
        assert!(fp.chars().all(|c| c.is_ascii_hexdigit()));
        assert_eq!(fp, HwTarget::cortex_a72().fingerprint_hex(), "stable");
        assert_ne!(fp, a.float_only().fingerprint_hex(), "capability-sensitive");
    }
}
