//! Measured-latency profiler: runs (layer, configuration) pairs on the
//! in-tree kernels and times them, instead of costing them analytically.
//!
//! The paper's central claim is that compression policies must be scored by
//! latency *measured on the target*, not by proxy metrics.  This module is
//! the measurement half of that claim for this reproduction: each `ModelIr`
//! layer under a `DiscretePolicy` is lowered to a GEMM of the layer's
//! im2col shape — `Mat::matmul` for FP32, the dynamic-quantize + `gemm_i8`
//! pipeline for INT8, and the pre-packed `gemm_i8_packed` pipeline for MIX
//! (the host has no bit-serial operator; the packed-i8 path is the closest
//! executable stand-in and is timed as such) — and measured in steady state:
//! warmup iterations, adaptively batched samples, trimmed-median + MAD
//! statistics, and an outlier-rejection re-run loop when the relative MAD
//! exceeds the configured limit.
//!
//! Results are cached twice:
//! * in memory per `(layer shape, eff_cin, kept_channels, effective mode)`
//!   config key, so a search measures each distinct configuration once;
//! * on disk as a versioned profile manifest
//!   (`profiles/<target>/<model>.json`) with a schema version and a target
//!   fingerprint, in the spirit of the RFC-0005 artifact format — a repeated
//!   search against the same target re-measures nothing (asserted via
//!   `stats().measured`).
//!
//! Measurement is fallible on real devices, so it degrades instead of
//! failing: each configuration is retried with deterministic backoff
//! (`retry_attempts`/`retry_base`), and a configuration whose measurement
//! attempts are exhausted falls back to the *calibrated analytical* cost —
//! the `CostModel` estimate scaled by the least-squares ratio fitted
//! against this session's successful measurements (the same per-class fit
//! `HybridProvider::calibrate` uses).  Degraded entries are flagged
//! (`ProfileEntry::degraded`, counted by `stats().degraded`), excluded from
//! the on-disk manifest (it must only contain real measurements), never
//! published to the shared sweep cache (a healthier worker should measure
//! for real; a worker that nevertheless *adopts* a degraded entry counts it
//! toward its own `degraded` stat), and surfaced in the provider's
//! `backend()` provenance label.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::cost::CostModel;
use super::shared::SharedProfileCache;
use super::target::HwTarget;
use crate::compress::{DiscretePolicy, QuantMode};
use crate::model::{Layer, LayerKind, ModelIr};
use crate::obs;
use crate::tensor::depthwise::{conv_dw_f32, conv_dw_i8, QuantizedDwWeights};
use crate::tensor::quant::{gemm_i8, gemm_i8_packed, QuantizedMat, QuantizedTensor};
use crate::tensor::Mat;
use crate::testing::FaultPlan;
use crate::util::json::Json;
use crate::util::retry::Backoff;
use crate::util::rng::Pcg64;
use crate::util::stats::median;
use crate::util::Fnv1a;

/// Bump when the on-disk manifest layout changes; mismatched caches are
/// ignored (never mis-parsed).
pub const PROFILE_SCHEMA_VERSION: usize = 1;

// Process-wide registry aggregates of the per-instance `ProfilerStats`
// counters: every profiler increments the same series at the same sites,
// so the `metrics` snapshot is the one process-level source of truth
// while `stats()` remains the exact per-object view the tests (and the
// `backend()` provenance label) rely on.

fn obs_cache_hits() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("latency_cache_hits_total", &[("cache", "profile")]))
}

fn obs_measurements() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("profiler_measurements_total", &[]))
}

fn obs_degraded() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("profiler_degraded_total", &[]))
}

fn obs_reruns() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("profiler_outlier_reruns_total", &[]))
}

/// Per-mode-class steady-state measurement latency histograms
/// (`class_id` 0/1/2 = fp32/int8/mix), standard deterministic layout.
fn obs_measure_hist(class_id: u64) -> &'static obs::Histogram {
    static H: OnceLock<[obs::Histogram; QuantMode::CLASSES]> = OnceLock::new();
    let all = H.get_or_init(|| {
        let bounds = obs::latency_bounds();
        ["fp32", "int8", "mix"].map(|class| {
            obs::Histogram::register("profiler_measure_seconds", &[("class", class)], &bounds)
        })
    });
    &all[(class_id as usize).min(QuantMode::CLASSES - 1)]
}

/// Measurement-harness knobs.
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Untimed iterations before sampling (cache/branch-predictor warmup).
    pub warmup_iters: usize,
    /// Timed samples per configuration (each sample batches enough
    /// iterations to fill `min_sample_time`).
    pub samples: usize,
    /// Minimum wall time per sample: batches tiny kernels so the timer
    /// granularity does not dominate.
    pub min_sample_time: Duration,
    /// Fraction trimmed from each tail before the median (outlier guard).
    pub trim_frac: f64,
    /// Re-measure when `MAD > rel_mad_limit * median` (noisy run detected).
    pub rel_mad_limit: f64,
    /// Re-measurement attempts before accepting the last (still-noisy) run.
    pub max_reruns: usize,
    /// Attempts per configuration before degrading to the calibrated
    /// analytical fallback (>= 1; transient failures are retried with
    /// deterministic backoff).
    pub retry_attempts: u32,
    /// Base delay of the retry backoff (doubled per attempt, jittered).
    pub retry_base: Duration,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            samples: 12,
            min_sample_time: Duration::from_millis(2),
            trim_frac: 0.2,
            rel_mad_limit: 0.10,
            max_reruns: 2,
            retry_attempts: 3,
            retry_base: Duration::from_millis(10),
        }
    }
}

impl ProfilerConfig {
    /// Minimal-cost settings for tests and CI smoke runs: single-shot
    /// sampling, no re-run loop, near-zero batching floor, near-zero retry
    /// delays (the retry *count* stays, so fault-injection tests exercise
    /// the same path the defaults run).
    pub fn fast() -> Self {
        Self {
            warmup_iters: 1,
            samples: 3,
            min_sample_time: Duration::from_micros(50),
            trim_frac: 0.34,
            rel_mad_limit: f64::INFINITY,
            max_reruns: 0,
            retry_attempts: 3,
            retry_base: Duration::from_micros(1),
        }
    }
}

/// One measured configuration in the profile cache.
#[derive(Clone, Debug)]
pub struct ProfileEntry {
    /// Trimmed-median steady-state latency (seconds).
    pub latency_s: f64,
    /// Median absolute deviation of the kept samples (seconds).
    pub mad_s: f64,
    /// Samples in the accepted run.
    pub samples: usize,
    /// Layer name at measurement time (diagnostic only — the key is the
    /// shape, so identical twins share an entry).
    pub layer: String,
    /// Effective quantization mode label.
    pub mode: String,
    /// True when measurement was exhausted and this value is the calibrated
    /// analytical fallback, not a real measurement (never persisted to the
    /// on-disk manifest).
    pub degraded: bool,
}

/// Cache/measurement counters since construction.
///
/// This is the exact **per-instance** view (what `backend()` provenance
/// and the unit tests rely on); every event behind it also increments the
/// process-wide metrics registry at the same site
/// (`profiler_measurements_total`, `profiler_degraded_total`,
/// `profiler_outlier_reruns_total`,
/// `latency_cache_hits_total{cache="profile"}` and the per-class
/// `profiler_measure_seconds` histograms), which is the aggregate the
/// `metrics` serve verb and `galen report --metrics` surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProfilerStats {
    /// Lookups served from the cache (memory or disk-loaded).
    pub hits: u64,
    /// Configurations actually measured this session.
    pub measured: u64,
    /// Entries loaded from the on-disk manifest at construction.
    pub loaded: usize,
    /// Total entries currently cached.
    pub entries: usize,
    /// Configurations served by the calibrated analytical fallback — either
    /// measured here with exhausted retries, or adopted from a sweep peer's
    /// degraded entry.  Nonzero flips `backend()` to the fallback label.
    pub degraded: u64,
}

/// Measures real kernel latencies per layer configuration, with an on-disk
/// profile cache.  Plugs into the search loop via `hw::LatencyProvider`.
#[derive(Debug)]
pub struct MeasuredProfiler {
    /// Measurement-harness knobs (warmup, samples, re-run policy).
    pub cfg: ProfilerConfig,
    /// Mode-support fallback (MIX -> INT8 -> FP32) mirrors the deployed
    /// runtime, so probing unsupported configurations measures what would
    /// actually run.
    cost: CostModel,
    model: String,
    cache_path: Option<PathBuf>,
    entries: HashMap<u64, ProfileEntry>,
    /// Cross-worker measurement cache (sweep orchestrator); consulted after
    /// the local map, published to after every measurement.
    shared: Option<SharedProfileCache>,
    /// Armed fault injections (tests; empty in production).
    faults: FaultPlan,
    /// Running least-squares sums of `sim/measured` ratios per mode class,
    /// fitted from this session's successful measurements — the scale the
    /// analytical fallback applies when measurement is exhausted.
    calib_num: [f64; QuantMode::CLASSES],
    calib_den: [f64; QuantMode::CLASSES],
    hits: u64,
    measured: u64,
    loaded: usize,
    degraded: u64,
    dirty: bool,
    /// Autotuned kernel tile config for this host/target (Some for
    /// disk-backed profilers: loaded from the manifest, or measured once
    /// by `tensor::simd::autotune` and persisted — zero re-tune on the
    /// second run).
    tile: Option<crate::tensor::simd::TileConfig>,
}

impl MeasuredProfiler {
    /// In-memory profiler (no disk cache).
    pub fn new(target: HwTarget, model: &str, cfg: ProfilerConfig) -> Self {
        Self {
            cfg,
            cost: CostModel::new(target),
            model: model.to_string(),
            cache_path: None,
            entries: HashMap::new(),
            shared: None,
            faults: FaultPlan::none(),
            calib_num: [0.0; QuantMode::CLASSES],
            calib_den: [0.0; QuantMode::CLASSES],
            hits: 0,
            measured: 0,
            loaded: 0,
            degraded: 0,
            dirty: false,
            tile: None,
        }
    }

    /// Attach a cross-worker measurement cache (parallel sweeps): any
    /// configuration measured by a profiler sharing the handle is reused
    /// here instead of being re-timed, and the first published measurement
    /// is canonical for every worker.
    pub fn with_shared_cache(mut self, cache: SharedProfileCache) -> Self {
        self.shared = Some(cache);
        self
    }

    /// Arm fault injections on the measurement and manifest-write paths
    /// (site `measure` per attempt, `profile-write` per save).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Profiler backed by `dir/<target>/<model>.json`; loads any existing
    /// manifest whose schema version, target fingerprint, and host kernel
    /// ISA match.  If the manifest carries no tile config (fresh cache, or
    /// one recorded by an older session), the kernel autotuner runs once
    /// here and the winner is persisted with the measurements — so every
    /// later `bench_layer` times the tuned kernels and second runs re-tune
    /// nothing.
    pub fn with_cache(
        target: HwTarget,
        model: &str,
        cfg: ProfilerConfig,
        dir: &Path,
    ) -> Result<Self> {
        let path = dir
            .join(sanitize(&target.name))
            .join(format!("{model}.json"));
        let mut p = Self::new(target, model, cfg);
        p.cache_path = Some(path.clone());
        // reap temp files a crashed process left between create and rename
        crate::util::json::cleanup_stale_temps(&path);
        if path.exists() {
            match p.load_manifest(&path) {
                Ok(n) => {
                    p.loaded = n;
                    log::info!("profile cache: loaded {n} entries from {}", path.display());
                }
                Err(e) => {
                    p.entries.clear(); // drop any partially loaded state
                    p.tile = None;
                    log::warn!(
                        "profile cache {} ignored ({e:#}); starting empty",
                        path.display()
                    );
                }
            }
        }
        match p.tile {
            Some(t) => crate::tensor::simd::set_tile_config(t),
            None => {
                let t = crate::tensor::simd::autotune();
                crate::tensor::simd::set_tile_config(t);
                p.tile = Some(t);
                p.dirty = true; // persist the tuning with the measurements
            }
        }
        Ok(p)
    }

    /// The kernel tile config this profiler runs under (None for in-memory
    /// profilers, which never autotune).
    pub fn tile_config(&self) -> Option<crate::tensor::simd::TileConfig> {
        self.tile
    }

    /// The hardware target whose kernel selection this profiler mirrors.
    pub fn target(&self) -> &HwTarget {
        &self.cost.target
    }

    /// Cache/measurement counters since construction.
    pub fn stats(&self) -> ProfilerStats {
        ProfilerStats {
            hits: self.hits,
            measured: self.measured,
            loaded: self.loaded,
            entries: self.entries.len(),
            degraded: self.degraded,
        }
    }

    /// Where the on-disk manifest lives (None for in-memory profilers).
    pub fn cache_path(&self) -> Option<&Path> {
        self.cache_path.as_deref()
    }

    /// Measured steady-state latency of one layer configuration (seconds),
    /// served from the cache when the configuration is known.
    pub fn layer_latency(
        &mut self,
        l: &Layer,
        eff_cin: usize,
        kept: usize,
        quant: QuantMode,
    ) -> f64 {
        let mode = self.cost.effective_mode(l, eff_cin, kept, quant);
        let key = config_key(l, eff_cin, kept, mode);
        if let Some(e) = self.entries.get(&key) {
            self.hits += 1;
            obs_cache_hits().inc();
            return e.latency_s;
        }
        if let Some(e) = self.shared.as_ref().and_then(|s| s.get(key)) {
            // another sweep worker already measured this configuration;
            // adopt its canonical entry (and persist it with ours).  An
            // adopted fallback value counts toward OUR degraded stat too —
            // provenance (`backend()`) must report that this provider serves
            // analytical values, whoever computed them
            self.hits += 1;
            obs_cache_hits().inc();
            self.dirty = true;
            if e.degraded {
                self.degraded += 1;
                obs_degraded().inc();
            }
            let latency_s = e.latency_s;
            self.entries.insert(key, e);
            return latency_s;
        }
        let mut entry = self.bench_with_retry(l, eff_cin, kept, mode, key);
        if !entry.degraded {
            if let Some(shared) = &self.shared {
                // first publication wins; a racing worker's entry supersedes
                // ours.  Degraded (analytical-fallback) entries are never
                // published: a fallback must not become canonical for the
                // whole sweep when a healthier worker could still measure
                entry = shared.insert_or_get(key, entry);
            }
        }
        let latency_s = entry.latency_s;
        self.entries.insert(key, entry);
        latency_s
    }

    /// Measure one configuration, retrying transient failures with
    /// deterministic backoff; when every attempt fails, degrade to the
    /// calibrated analytical estimate instead of failing the search.
    fn bench_with_retry(
        &mut self,
        l: &Layer,
        eff_cin: usize,
        kept: usize,
        mode: QuantMode,
        key: u64,
    ) -> ProfileEntry {
        let _sp = obs::trace::span("measure")
            .arg("layer", l.name.clone())
            .arg("mode", mode.label());
        let backoff = Backoff::new(
            self.cfg.retry_attempts,
            self.cfg.retry_base,
            self.cfg.retry_base.saturating_mul(16),
            key,
        );
        let faults = &self.faults;
        let cfg = &self.cfg;
        let measured = backoff.run(|_| {
            faults.trip("measure")?;
            let (latency_s, mad_s, samples) = bench_layer(cfg, l, eff_cin, kept, mode, key);
            anyhow::ensure!(
                latency_s.is_finite() && latency_s > 0.0,
                "implausible measurement {latency_s}s for layer '{}'",
                l.name
            );
            Ok((latency_s, mad_s, samples))
        });
        self.dirty = true;
        match measured {
            Ok((latency_s, mad_s, samples)) => {
                self.measured += 1;
                obs_measurements().inc();
                obs_measure_hist(mode.class_id()).observe(latency_s);
                // feed the fallback calibration: least squares on the
                // relative residual, per mode class (same fit as
                // HybridProvider::calibrate)
                let sim_t = self.cost.layer_total(l, eff_cin, kept, mode);
                if sim_t > 0.0 {
                    let r = sim_t / latency_s;
                    let c = mode.class_id() as usize;
                    self.calib_num[c] += r;
                    self.calib_den[c] += r * r;
                }
                ProfileEntry {
                    latency_s,
                    mad_s,
                    samples,
                    layer: l.name.clone(),
                    mode: mode.label(),
                    degraded: false,
                }
            }
            Err(e) => {
                self.degraded += 1;
                obs_degraded().inc();
                let c = mode.class_id() as usize;
                let scale = if self.calib_den[c] > 0.0 {
                    self.calib_num[c] / self.calib_den[c]
                } else {
                    1.0
                };
                let latency_s = scale * self.cost.layer_total(l, eff_cin, kept, mode);
                log::warn!(
                    "profiler: measurement of '{}' exhausted retries ({e:#}); \
                     using calibrated analytical fallback {latency_s:.3e}s",
                    l.name
                );
                ProfileEntry {
                    latency_s,
                    mad_s: 0.0,
                    samples: 0,
                    layer: l.name.clone(),
                    mode: mode.label(),
                    degraded: true,
                }
            }
        }
    }

    /// Fold every entry of the attached shared cache into the local map
    /// (no-op without one).  Returns how many entries were new.  The sweep
    /// orchestrator calls this once after all workers finish, so a single
    /// disk-backed profiler can persist the whole sweep's measurements
    /// without concurrent manifest writes.
    pub fn absorb_shared(&mut self) -> usize {
        let Some(shared) = self.shared.clone() else {
            return 0;
        };
        let mut added = 0;
        for (key, entry) in shared.snapshot() {
            if let std::collections::hash_map::Entry::Vacant(v) = self.entries.entry(key) {
                v.insert(entry);
                added += 1;
            }
        }
        if added > 0 {
            self.dirty = true;
        }
        added
    }

    /// Cache-only lookup: no measurement, no counter updates.  Used by the
    /// hybrid provider to fall back to the calibrated simulator for
    /// configurations that were never measured.
    pub fn lookup(&self, l: &Layer, eff_cin: usize, kept: usize, quant: QuantMode) -> Option<f64> {
        let mode = self.cost.effective_mode(l, eff_cin, kept, quant);
        self.entries.get(&config_key(l, eff_cin, kept, mode)).map(|e| e.latency_s)
    }

    /// Measured end-to-end latency of a compressed model (sum of per-layer
    /// steady-state medians).
    pub fn model_latency(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> f64 {
        self.model_latency_per_layer(ir, policy).iter().sum()
    }

    /// Per-layer measured latency breakdown.
    pub fn model_latency_per_layer(&mut self, ir: &ModelIr, policy: &DiscretePolicy) -> Vec<f64> {
        ir.layers
            .iter()
            .map(|l| {
                let cmp = &policy.layers[l.index];
                let eff_cin = policy.effective_cin(ir, l.index);
                self.layer_latency(l, eff_cin, cmp.kept_channels, cmp.quant)
            })
            .collect()
    }

    /// Write the profile manifest (when disk-backed and dirty).  Returns the
    /// path written, if any.  Degraded (analytical-fallback) entries are
    /// not persisted: the manifest is a record of real measurements, and a
    /// fallback must be retried, not cached across sessions.
    pub fn save(&mut self) -> Result<Option<PathBuf>> {
        let Some(path) = self.cache_path.clone() else {
            return Ok(None);
        };
        if !self.dirty {
            return Ok(Some(path));
        }
        let mut entries = std::collections::BTreeMap::new();
        for (key, e) in self.entries.iter().filter(|(_, e)| !e.degraded) {
            entries.insert(
                format!("{key:016x}"),
                Json::obj(vec![
                    ("latency_s", Json::num(e.latency_s)),
                    ("mad_s", Json::num(e.mad_s)),
                    ("samples", Json::num(e.samples as f64)),
                    ("layer", Json::str(e.layer.clone())),
                    ("mode", Json::str(e.mode.clone())),
                ]),
            );
        }
        let mut fields = vec![
            ("schema_version", Json::num(PROFILE_SCHEMA_VERSION as f64)),
            ("model", Json::str(self.model.clone())),
            ("target", Json::str(self.cost.target.name.clone())),
            (
                "target_fingerprint",
                Json::str(format!("{:016x}", target_fingerprint(&self.cost.target))),
            ),
            ("entries", Json::Obj(entries)),
        ];
        // Optional tuning provenance (same schema version — old readers
        // ignore unknown keys).  `host_isa` guards the measurements: a
        // cache timed under one kernel backend must not feed latencies to
        // another, so loads reject on mismatch.  The tile config is NOT
        // part of the target fingerprint — it is a host-side perf hint,
        // never results-affecting, and artifacts must stay byte-identical
        // across dispatch modes.
        if let Some(t) = self.tile {
            fields.push((
                "tile",
                Json::obj(vec![
                    ("kc", Json::num(t.kc as f64)),
                    ("mc", Json::num(t.mc as f64)),
                    ("par_min_macs", Json::num(t.par_min_macs as f64)),
                ]),
            ));
            fields.push((
                "host_isa",
                Json::str(crate::tensor::simd::isa_label().to_string()),
            ));
        }
        let manifest = Json::obj(fields);
        self.faults.trip("profile-write")?;
        // atomic: a crash mid-write must leave the previous manifest (or
        // nothing), never a truncated one for the next session to choke on
        manifest.write_file_atomic(&path)?;
        self.dirty = false;
        Ok(Some(path))
    }

    fn load_manifest(&mut self, path: &Path) -> Result<usize> {
        let j = Json::read_file(path)?;
        anyhow::ensure!(
            j.req_usize("schema_version")? == PROFILE_SCHEMA_VERSION,
            "schema version mismatch"
        );
        anyhow::ensure!(j.req_str("model")? == self.model, "model mismatch");
        let fp = format!("{:016x}", target_fingerprint(&self.cost.target));
        anyhow::ensure!(
            j.req_str("target_fingerprint")? == fp,
            "target fingerprint mismatch (target parameters changed)"
        );
        if let Some(hi) = j.get("host_isa").and_then(Json::as_str) {
            anyhow::ensure!(
                hi == crate::tensor::simd::isa_label(),
                "host ISA mismatch (cache measured under '{hi}', kernels now \
                 dispatch to '{}')",
                crate::tensor::simd::isa_label()
            );
        }
        if let Some(t) = j.get("tile") {
            self.tile = Some(
                crate::tensor::simd::TileConfig {
                    kc: t.req_usize("kc")?,
                    mc: t.req_usize("mc")?,
                    par_min_macs: t.req_usize("par_min_macs")?,
                }
                .sanitized(),
            );
        }
        let entries = j
            .req("entries")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("'entries' is not an object"))?;
        for (key, e) in entries {
            let key = u64::from_str_radix(key, 16)
                .map_err(|_| anyhow::anyhow!("bad entry key '{key}'"))?;
            let latency_s = e.req_f64("latency_s")?;
            anyhow::ensure!(
                latency_s.is_finite() && latency_s > 0.0,
                "entry {key:016x} has implausible latency {latency_s}"
            );
            self.entries.insert(
                key,
                ProfileEntry {
                    latency_s,
                    mad_s: e.req_f64("mad_s")?,
                    samples: e.req_usize("samples")?,
                    layer: e.req_str("layer")?.to_string(),
                    mode: e.req_str("mode")?.to_string(),
                    // only real measurements are persisted
                    degraded: false,
                },
            );
        }
        Ok(self.entries.len())
    }
}

/// Config key: FNV-1a over the shape-defining layer fields plus the
/// effective configuration.  Layer *identity* (index/name) is deliberately
/// excluded — two layers with identical shapes share one measurement.
pub(crate) fn config_key(l: &Layer, eff_cin: usize, kept: usize, mode: QuantMode) -> u64 {
    let mut h = Fnv1a::new();
    h.mix(matches!(l.kind, LayerKind::Conv) as u64);
    h.mix(l.kernel as u64);
    h.mix(l.stride as u64);
    h.mix(l.in_spatial as u64);
    h.mix(l.out_spatial as u64);
    h.mix(l.depthwise as u64);
    h.mix(eff_cin as u64);
    h.mix(kept as u64);
    h.mix(mode.class_id());
    let (wb, ab) = mode.bits();
    h.mix(((wb as u64) << 32) | ab as u64);
    h.finish()
}

/// Identity of a target's *measurement-relevant* parameters: kernel
/// selection depends on the support flags and the name; a cache produced
/// under different support flags must not be reused.
pub(crate) fn target_fingerprint(t: &HwTarget) -> u64 {
    let mut h = Fnv1a::new();
    h.mix_bytes(t.name.as_bytes());
    h.mix(t.supports_int8 as u64);
    h.mix(t.supports_bitserial as u64);
    h.finish()
}

/// File-system-safe directory name for a target (shared with the sweep
/// artifact layout, so `profiles/<target>/` and `sweeps/<target>/` agree).
pub(crate) fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '-' })
        .collect()
}

/// GEMM shape a *dense* layer lowers to (im2col): `m x k x n` =
/// `out_spatial^2 x kernel^2*cin x cout` for convs, `1 x cin x cout` for
/// linear layers — `m*k*n` equals the layer's MAC count, so measured time
/// and the analytical compute term describe the same work.  Depthwise convs
/// do not lower to a GEMM; `bench_layer` runs the dedicated windowed
/// kernels (`tensor::depthwise`) for them instead.
fn gemm_shape(l: &Layer, eff_cin: usize, kept: usize) -> (usize, usize, usize) {
    match l.kind {
        LayerKind::Conv => (
            l.out_spatial * l.out_spatial,
            l.kernel * l.kernel * eff_cin,
            kept,
        ),
        LayerKind::Linear => (1, eff_cin, kept),
    }
}

/// Measure one lowered depthwise configuration in steady state: the
/// surviving `min(eff_cin, kept)` channels run the real windowed kernels —
/// `conv_dw_f32` for FP32, dynamic-quantize + `conv_dw_i8` for INT8 (MIX
/// never reaches a depthwise layer: the operator constraints exclude it and
/// `effective_mode` has already fallen back, but the INT8 kernel stands in
/// defensively should a caller probe the raw mode).
fn bench_depthwise_layer(
    cfg: &ProfilerConfig,
    l: &Layer,
    eff_cin: usize,
    kept: usize,
    mode: QuantMode,
    key: u64,
) -> (f64, f64, usize) {
    let channels = eff_cin.min(kept).max(1);
    let (in_sp, out_sp) = (l.in_spatial, l.out_spatial);
    let mut rng = Pcg64::with_stream(key, 0xd3f1);
    let mut input = Mat::zeros(channels, in_sp * in_sp);
    let mut weights = vec![0.0f32; channels * l.kernel * l.kernel];
    for x in input.data.iter_mut().chain(&mut weights) {
        *x = rng.next_f32() * 2.0 - 1.0;
    }
    let mut out = vec![0.0f32; channels * out_sp * out_sp];
    match mode {
        QuantMode::Fp32 => run_steady_state(cfg, || {
            conv_dw_f32(
                &input.data,
                channels,
                in_sp,
                out_sp,
                l.kernel,
                l.stride,
                &weights,
                &mut out,
            )
        }),
        QuantMode::Int8 | QuantMode::Mix { .. } => {
            // weights quantized offline; activations dynamically per call
            let qw = QuantizedDwWeights::quantize(&weights, channels, l.kernel);
            let mut qa = QuantizedTensor::quantize(&input);
            run_steady_state(cfg, || {
                qa.requantize(&input);
                conv_dw_i8(
                    &qa.data, qa.scale, channels, in_sp, out_sp, l.stride, &qw, &mut out,
                );
            })
        }
    }
}

/// Measure one lowered layer configuration in steady state.  Returns
/// `(trimmed_median_s, mad_s, samples)`.
fn bench_layer(
    cfg: &ProfilerConfig,
    l: &Layer,
    eff_cin: usize,
    kept: usize,
    mode: QuantMode,
    key: u64,
) -> (f64, f64, usize) {
    if l.depthwise {
        return bench_depthwise_layer(cfg, l, eff_cin, kept, mode, key);
    }
    let (m, k, n) = gemm_shape(l, eff_cin, kept);
    // deterministic operand fill so every process measures identical work
    let mut rng = Pcg64::with_stream(key, 0xbe9c);
    let mut a = Mat::zeros(m, k);
    let mut w = Mat::zeros(k, n);
    for x in a.data.iter_mut().chain(&mut w.data) {
        *x = rng.next_f32() * 2.0 - 1.0;
    }
    let mut out = Mat::zeros(m, n);
    match mode {
        QuantMode::Fp32 => {
            // serial kernel: measurement must not inherit thread-pool jitter
            run_steady_state(cfg, || a.matmul_into_threaded(&w, &mut out, 1))
        }
        QuantMode::Int8 => {
            // weights quantized offline; activations dynamically per call
            // (the per-call quantize overhead is part of what INT8 costs)
            let qw = QuantizedMat::quantize_per_channel(&w);
            let mut qa = QuantizedTensor::quantize(&a);
            let mut acc: Vec<i32> = Vec::new();
            run_steady_state(cfg, || {
                qa.requantize(&a);
                gemm_i8(&qa, &qw, &mut acc, &mut out);
            })
        }
        QuantMode::Mix { .. } => {
            // no host bit-serial operator exists: the pre-packed i8 path is
            // the executable stand-in (weights packed offline, like TVM's
            // bit-serial weight pre-packing)
            let packed = QuantizedMat::quantize_per_channel(&w).pack();
            let mut qa = QuantizedTensor::quantize(&a);
            let mut acc: Vec<i32> = Vec::new();
            run_steady_state(cfg, || {
                qa.requantize(&a);
                gemm_i8_packed(&qa, &packed, &mut acc, &mut out);
            })
        }
    }
}

/// The harness core: warmup, adaptive batching, trimmed-median + MAD, and
/// the outlier-rejection re-run loop.
fn run_steady_state(cfg: &ProfilerConfig, mut run: impl FnMut()) -> (f64, f64, usize) {
    for _ in 0..cfg.warmup_iters {
        run();
    }
    // calibrate the per-sample batch so timer granularity cannot dominate
    let t0 = Instant::now();
    run();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((cfg.min_sample_time.as_secs_f64() / once).ceil() as u64).clamp(1, 100_000);

    let mut attempt = 0;
    loop {
        let mut samples = Vec::with_capacity(cfg.samples.max(1));
        for _ in 0..cfg.samples.max(1) {
            let t = Instant::now();
            for _ in 0..iters {
                run();
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let (med, mad) = trimmed_median_mad(&samples, cfg.trim_frac);
        if mad <= cfg.rel_mad_limit * med || attempt >= cfg.max_reruns {
            if attempt > 0 {
                obs_reruns().add(attempt as u64);
            }
            return (med, mad, samples.len());
        }
        attempt += 1;
    }
}

/// Sort, trim `trim_frac` from each tail (keeping at least one sample), and
/// return (median, median-absolute-deviation) of the kept slice.
fn trimmed_median_mad(xs: &[f64], trim_frac: f64) -> (f64, f64) {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut = ((v.len() as f64) * trim_frac).floor() as usize;
    let keep = if v.len() > 2 * cut {
        &v[cut..v.len() - cut]
    } else {
        &v[v.len() / 2..v.len() / 2 + 1]
    };
    let med = median(keep);
    let devs: Vec<f64> = keep.iter().map(|x| (x - med).abs()).collect();
    (med, median(&devs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ir::test_fixtures::tiny_meta;
    use crate::model::ModelIr;

    fn ir() -> ModelIr {
        ModelIr::from_meta(&tiny_meta()).unwrap()
    }

    fn fast_profiler() -> MeasuredProfiler {
        MeasuredProfiler::new(HwTarget::cortex_a72(), "tiny", ProfilerConfig::fast())
    }

    #[test]
    fn measures_positive_latency_and_caches() {
        let ir = ir();
        let mut p = fast_profiler();
        let policy = DiscretePolicy::reference(&ir);
        let t1 = p.model_latency(&ir, &policy);
        assert!(t1 > 0.0);
        let measured_after_first = p.stats().measured;
        assert!(measured_after_first > 0);
        // identical policy: every config is a cache hit
        let t2 = p.model_latency(&ir, &policy);
        assert_eq!(t1, t2, "cached values must be returned verbatim");
        assert_eq!(p.stats().measured, measured_after_first);
        assert!(p.stats().hits >= ir.layers.len() as u64);
    }

    #[test]
    fn distinct_modes_measure_distinct_configs() {
        let ir = ir();
        let mut p = fast_profiler();
        let fp32 = DiscretePolicy::reference(&ir);
        let mut int8 = fp32.clone();
        for l in &mut int8.layers {
            l.quant = QuantMode::Int8;
        }
        p.model_latency(&ir, &fp32);
        let after_fp32 = p.stats().measured;
        p.model_latency(&ir, &int8);
        assert!(
            p.stats().measured > after_fp32,
            "INT8 configs must not collide with FP32 entries"
        );
    }

    #[test]
    fn float_only_target_folds_quant_modes_together() {
        let ir = ir();
        let mut p = MeasuredProfiler::new(
            HwTarget::cortex_a72().float_only(),
            "tiny",
            ProfilerConfig::fast(),
        );
        let fp32 = DiscretePolicy::reference(&ir);
        let mut int8 = fp32.clone();
        for l in &mut int8.layers {
            l.quant = QuantMode::Int8;
        }
        p.model_latency(&ir, &fp32);
        let after_fp32 = p.stats().measured;
        // on a float-only device INT8 falls back to FP32: all cache hits
        p.model_latency(&ir, &int8);
        assert_eq!(p.stats().measured, after_fp32);
    }

    #[test]
    fn per_layer_breakdown_sums_to_total() {
        let ir = ir();
        let mut p = fast_profiler();
        let policy = DiscretePolicy::reference(&ir);
        let per_layer = p.model_latency_per_layer(&ir, &policy);
        assert_eq!(per_layer.len(), ir.layers.len());
        let total = p.model_latency(&ir, &policy);
        assert!((per_layer.iter().sum::<f64>() - total).abs() < 1e-12);
    }

    #[test]
    fn config_key_separates_configurations() {
        let ir = ir();
        let l = &ir.layers[1];
        let base = config_key(l, l.cin, l.cout, QuantMode::Fp32);
        assert_ne!(base, config_key(l, l.cin, l.cout - 1, QuantMode::Fp32));
        assert_ne!(base, config_key(l, l.cin - 1, l.cout, QuantMode::Fp32));
        assert_ne!(base, config_key(l, l.cin, l.cout, QuantMode::Int8));
        assert_ne!(
            config_key(l, l.cin, l.cout, QuantMode::Int8),
            config_key(l, l.cin, l.cout, QuantMode::Mix { w_bits: 8, a_bits: 8 }),
            "MIX(8/8) must not collide with INT8"
        );
    }

    #[test]
    fn shared_cache_reuses_measurements_across_profilers() {
        let ir = ir();
        let shared = SharedProfileCache::new();
        let mut a = fast_profiler().with_shared_cache(shared.clone());
        let mut b = fast_profiler().with_shared_cache(shared.clone());
        let policy = DiscretePolicy::reference(&ir);
        let ta = a.model_latency(&ir, &policy);
        assert!(a.stats().measured > 0);
        assert_eq!(shared.len(), a.stats().entries);
        // the second profiler re-times nothing and returns identical values
        let tb = b.model_latency(&ir, &policy);
        assert_eq!(b.stats().measured, 0, "shared entries must be reused");
        assert_eq!(ta, tb);
        // absorb_shared on a fresh profiler imports every sweep measurement
        let mut c = fast_profiler().with_shared_cache(shared.clone());
        assert_eq!(c.absorb_shared(), shared.len());
        assert_eq!(c.stats().entries, shared.len());
        assert_eq!(c.absorb_shared(), 0, "second absorb adds nothing");
    }

    #[test]
    fn manifest_roundtrip_and_fingerprint_guard() {
        let ir = ir();
        let dir = std::env::temp_dir().join(format!("galen_profiler_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut p1 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        let policy = DiscretePolicy::reference(&ir);
        let t1 = p1.model_latency(&ir, &policy);
        let path = p1.save().unwrap().expect("disk-backed");
        assert!(path.exists());

        // reload: entries come back, values identical, nothing re-measured
        let mut p2 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        assert_eq!(p2.stats().loaded, p1.stats().entries);
        let t2 = p2.model_latency(&ir, &policy);
        assert_eq!(t1, t2);
        assert_eq!(p2.stats().measured, 0);

        // a different target fingerprint must reject the cache
        let p3 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72().float_only(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        );
        // float_only changes the directory (name changed) -> empty cache;
        // force the same path by writing a manifest with the wrong target
        assert_eq!(p3.unwrap().stats().loaded, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The autotune contract: a disk-backed profiler tunes once, persists
    /// the tile next to the fingerprint, and a second run loads it without
    /// re-tuning; a cache measured under a different kernel ISA is
    /// rejected wholesale (its latencies timed different kernels).
    #[test]
    fn tile_config_is_persisted_and_not_retuned() {
        let _g = crate::tensor::simd::TEST_GLOBALS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let saved_tile = crate::tensor::simd::tile_config();
        let dir = std::env::temp_dir().join(format!("galen_profiler_tile_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut p1 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        let tile = p1.tile_config().expect("disk-backed profilers autotune");
        assert_eq!(tile.kc % 4, 0);
        assert!(fast_profiler().tile_config().is_none(), "in-memory: no autotune");
        let path = p1.save().unwrap().expect("disk-backed");

        let manifest = Json::read_file(&path).unwrap();
        assert!(manifest.get("tile").is_some(), "tile must be persisted");
        assert_eq!(
            manifest.get("host_isa").and_then(Json::as_str),
            Some(crate::tensor::simd::isa_label())
        );

        // Plant a distinctive (results-neutral) tile in the manifest: the
        // only way a second run can come up with it is by loading it, so
        // this proves zero-re-tune even though autotune() is memoized.
        let planted = crate::tensor::simd::TileConfig { kc: 12, mc: 7, par_min_macs: 999_424 };
        let mut j = manifest;
        if let Json::Obj(m) = &mut j {
            m.insert(
                "tile".into(),
                Json::obj(vec![
                    ("kc", Json::num(planted.kc as f64)),
                    ("mc", Json::num(planted.mc as f64)),
                    ("par_min_macs", Json::num(planted.par_min_macs as f64)),
                ]),
            );
        }
        j.write_file_atomic(&path).unwrap();
        let runs = crate::tensor::simd::autotune_runs();
        let p2 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        assert_eq!(
            p2.tile_config(),
            Some(planted),
            "second run must load the persisted tile, not re-tune"
        );
        assert_eq!(crate::tensor::simd::autotune_runs(), runs);

        // tamper the recorded ISA: the whole cache must be rejected
        if let Json::Obj(m) = &mut j {
            m.insert("host_isa".into(), Json::str("mips-msa"));
        }
        j.write_file_atomic(&path).unwrap();
        let p3 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        assert_eq!(p3.stats().loaded, 0, "foreign-ISA cache must not be loaded");
        assert_ne!(p3.tile_config(), Some(planted), "rejected cache must not supply the tile");

        crate::tensor::simd::set_tile_config(saved_tile);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exhausted_measurement_degrades_to_analytical_fallback() {
        let ir = ir();
        // first layer: 3 attempts all fail -> degraded; later layers
        // measure normally (the armed faults are spent)
        let mut p = fast_profiler()
            .with_faults(FaultPlan::parse("measure:1:io-error,measure:2:io-error,measure:3:io-error").unwrap());
        let policy = DiscretePolicy::reference(&ir);
        let total = p.model_latency(&ir, &policy);
        assert!(total > 0.0 && total.is_finite());
        assert_eq!(p.stats().degraded, 1, "exactly one config exhausted its retries");
        assert!(p.stats().measured >= 1, "the remaining configs still measure");
        // the degraded value is served from the cache like any other
        let again = p.model_latency(&ir, &policy);
        assert_eq!(total, again);
        assert_eq!(p.stats().degraded, 1);
    }

    #[test]
    fn transient_measurement_failure_is_retried_not_degraded() {
        let ir = ir();
        // one armed failure, three attempts: the retry absorbs it
        let mut p = fast_profiler().with_faults(FaultPlan::parse("measure:1:io-error").unwrap());
        let policy = DiscretePolicy::reference(&ir);
        assert!(p.model_latency(&ir, &policy) > 0.0);
        assert_eq!(p.stats().degraded, 0);
        assert!(p.stats().measured > 0);
    }

    #[test]
    fn degraded_entries_are_not_published_to_the_shared_cache() {
        let ir = ir();
        let shared = SharedProfileCache::new();
        // worker A degrades its first config (3 exhausted attempts)
        let mut a = fast_profiler()
            .with_shared_cache(shared.clone())
            .with_faults(FaultPlan::parse("measure:1:io-error,measure:2:io-error,measure:3:io-error").unwrap());
        let policy = DiscretePolicy::reference(&ir);
        a.model_latency(&ir, &policy);
        assert_eq!(a.stats().degraded, 1);
        // the fallback was NOT published: the shared cache only carries A's
        // real measurements
        assert_eq!(shared.len(), a.stats().entries - 1);
        // worker B re-measures the config A degraded on, for real
        let mut b = fast_profiler().with_shared_cache(shared.clone());
        b.model_latency(&ir, &policy);
        assert_eq!(b.stats().degraded, 0, "B must not inherit A's fallback");
        assert_eq!(b.stats().measured, 1, "B re-measures only the degraded config");
        assert_eq!(shared.len(), a.stats().entries, "B published the missing entry");
    }

    #[test]
    fn adopted_degraded_entry_counts_toward_provenance() {
        use crate::hw::LatencyProvider as _;
        let ir = ir();
        let shared = SharedProfileCache::new();
        // simulate a (hypothetical) degraded entry published to the shared
        // cache: any adopter must count it and flip its provenance label
        let l = &ir.layers[0];
        let key = config_key(l, l.cin, l.cout, QuantMode::Fp32);
        shared.insert_or_get(
            key,
            ProfileEntry {
                latency_s: 1e-6,
                mad_s: 0.0,
                samples: 0,
                layer: l.name.clone(),
                mode: "FP32".into(),
                degraded: true,
            },
        );
        let mut p = fast_profiler().with_shared_cache(shared);
        assert_eq!(p.backend(), "measured");
        p.layer_latency(l, l.cin, l.cout, QuantMode::Fp32);
        assert_eq!(p.stats().degraded, 1, "adoption must bump the adopter's stat");
        assert_eq!(p.backend(), "measured+analytical-fallback");
    }

    #[test]
    fn degraded_entries_are_not_persisted() {
        let ir = ir();
        let dir = std::env::temp_dir().join(format!("galen_profiler_degraded_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut p = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap()
        .with_faults(FaultPlan::parse("measure:1:io-error,measure:2:io-error,measure:3:io-error").unwrap());
        let policy = DiscretePolicy::reference(&ir);
        p.model_latency(&ir, &policy);
        assert_eq!(p.stats().degraded, 1);
        let entries = p.stats().entries;
        p.save().unwrap().expect("disk-backed");
        // reload: the degraded entry was dropped, so it will be re-measured
        let p2 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        assert_eq!(p2.stats().loaded, entries - 1, "degraded entry must not persist");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn profile_write_fault_surfaces_as_error() {
        let ir = ir();
        let dir = std::env::temp_dir().join(format!("galen_profiler_wfault_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut p = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap()
        .with_faults(FaultPlan::parse("profile-write:1:io-error").unwrap());
        p.model_latency(&ir, &DiscretePolicy::reference(&ir));
        let e = p.save().unwrap_err();
        assert!(format!("{e:#}").contains("injected fault"), "{e:#}");
        // the fault fired once; the retried save succeeds and the manifest
        // parses cleanly (atomic write: no truncated leftovers)
        let path = p.save().unwrap().expect("disk-backed");
        assert!(Json::read_file(&path).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_manifest_is_discarded_with_clean_restart() {
        let ir = ir();
        let dir = std::env::temp_dir().join(format!("galen_profiler_corrupt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut p = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        p.model_latency(&ir, &DiscretePolicy::reference(&ir));
        let path = p.save().unwrap().expect("disk-backed");
        // truncate the manifest mid-document (simulated crash without the
        // atomic writer) and reload: discarded with a warning, empty cache
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let p2 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        assert_eq!(p2.stats().loaded, 0, "corrupt manifest must be discarded");
        // implausible values are rejected too, not silently trusted
        std::fs::write(
            &path,
            text.replace("\"latency_s\":", "\"latency_s\": -1.0, \"x\":"),
        )
        .unwrap();
        let p3 = MeasuredProfiler::with_cache(
            HwTarget::cortex_a72(),
            "tiny",
            ProfilerConfig::fast(),
            &dir,
        )
        .unwrap();
        assert_eq!(p3.stats().loaded, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trimmed_median_mad_basics() {
        let (med, mad) = trimmed_median_mad(&[1.0, 1.0, 1.0, 1.0, 100.0], 0.2);
        assert_eq!(med, 1.0, "outlier must be trimmed");
        assert_eq!(mad, 0.0);
        let (med, _) = trimmed_median_mad(&[3.0], 0.4);
        assert_eq!(med, 3.0);
    }

    #[test]
    fn gemm_shape_preserves_mac_count() {
        let ir = ir();
        for l in &ir.layers {
            assert!(!l.depthwise, "dense-lowering invariant only");
            let (m, k, n) = gemm_shape(l, l.cin, l.cout);
            assert_eq!((m * k * n) as u64, l.macs(), "layer {}", l.name);
        }
    }

    #[test]
    fn depthwise_configs_measure_and_cache() {
        let ir = crate::model::ModelIr::from_meta(
            &crate::model::zoo::meta("mobilenetv2s").unwrap(),
        )
        .unwrap();
        let mut p = MeasuredProfiler::new(
            HwTarget::cortex_a72(),
            "mobilenetv2s",
            ProfilerConfig::fast(),
        );
        let dw = ir.layers.iter().find(|l| l.depthwise).unwrap();
        let fp32 = p.layer_latency(dw, dw.cin, dw.cout, QuantMode::Fp32);
        assert!(fp32 > 0.0 && fp32.is_finite());
        assert_eq!(p.stats().measured, 1);
        // the same config is a cache hit, a pruned one is a new measurement
        assert_eq!(p.layer_latency(dw, dw.cin, dw.cout, QuantMode::Fp32), fp32);
        assert_eq!(p.stats().measured, 1);
        let pruned = p.layer_latency(dw, dw.cin / 2, dw.cin / 2, QuantMode::Fp32);
        assert!(pruned > 0.0);
        assert_eq!(p.stats().measured, 2);
        // INT8 measures its own entry; a MIX probe folds onto it (depthwise
        // is excluded from bit-serial, so the effective mode is INT8)
        let int8 = p.layer_latency(dw, dw.cin, dw.cout, QuantMode::Int8);
        assert!(int8 > 0.0);
        assert_eq!(p.stats().measured, 3);
        let mix = p.layer_latency(dw, dw.cin, dw.cout, QuantMode::Mix { w_bits: 4, a_bits: 4 });
        assert_eq!(mix, int8, "MIX on depthwise must resolve to the INT8 entry");
        assert_eq!(p.stats().measured, 3);
    }

    #[test]
    fn mobilenet_model_latency_includes_depthwise_layers() {
        let ir = crate::model::ModelIr::from_meta(
            &crate::model::zoo::meta("mobilenetv2s").unwrap(),
        )
        .unwrap();
        let mut p = MeasuredProfiler::new(
            HwTarget::cortex_a72(),
            "mobilenetv2s",
            ProfilerConfig::fast(),
        );
        let policy = DiscretePolicy::reference(&ir);
        let per_layer = p.model_latency_per_layer(&ir, &policy);
        assert_eq!(per_layer.len(), ir.layers.len());
        for l in ir.layers.iter().filter(|l| l.depthwise) {
            assert!(per_layer[l.index] > 0.0, "{} measured nothing", l.name);
        }
    }
}
