//! Durable write-ahead job journal for `galen serve`.
//!
//! Every job lifecycle transition is appended as one JSONL line and
//! fsync'd before the service acts on it, so a crashed serve process can
//! be restarted with `--resume-jobs`: [`replay_journal`] folds the journal
//! into the last known state of every job, terminal jobs are restored as
//! status records, and non-terminal jobs are re-queued — resuming from
//! their per-episode checkpoints when present, or restarting from scratch
//! (searches are deterministic, so either path reproduces the
//! uninterrupted result bit for bit).
//!
//! Entry shapes (one compact JSON object per line, append-only):
//!
//! ```text
//! {"schema_version":1,"kind":"galen_serve_journal","job":"job-0","event":"submitted","config":{...}}
//! {"schema_version":1,"kind":"galen_serve_journal","job":"job-0","event":"status","status":"running"}
//! {"schema_version":1,"kind":"galen_serve_journal","job":"job-0","event":"status","status":"failed","error":"..."}
//! {"schema_version":1,"kind":"galen_serve_journal","job":"job-0","event":"resumed"}
//! ```
//!
//! `submitted` carries the full search configuration in the loss-free
//! checkpoint encoding (`SearchConfig::to_checkpoint_json`), so replay
//! needs nothing but the journal.  Replay is strict about interior
//! corruption (a clean, actionable error) but tolerates an unparseable
//! *final* line: a crash mid-append is exactly the failure this file
//! exists to survive.  [`ServeJournal::open_append`] truncates such a torn
//! tail before appending, so a resumed session's first record starts a
//! fresh line instead of concatenating onto the fragment (which would turn
//! a tolerated tail into hard interior corruption one restart later).

use std::path::{Path, PathBuf};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::Result;

use super::service::JobStatus;
use crate::obs;
use crate::search::SearchConfig;
use crate::testing::FaultPlan;
use crate::util::json::{fsync_dir, Json};

/// Write+fsync latency of one journal append (the durability cost every
/// job transition pays; `metrics` verb / `galen report --metrics`).
fn obs_append_seconds() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::Histogram::register("serve_journal_append_seconds", &[], &obs::latency_bounds())
    })
}

/// Jobs reconstructed by journal replays this process — the registry
/// aggregate behind the per-call `replay_journal(..).len()` view.
fn obs_replayed() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::Counter::register("serve_journal_replayed_jobs_total", &[]))
}

/// Bump when the journal line layout changes; mismatched journals are
/// rejected at replay (never mis-parsed).
pub const SERVE_JOURNAL_SCHEMA_VERSION: usize = 1;

/// The `kind` tag of every journal line.
const JOURNAL_KIND: &str = "galen_serve_journal";

/// File name of the journal inside the serve results directory.
pub const SERVE_JOURNAL_FILE: &str = "serve_journal.jsonl";

/// Append-side handle: one open file, every record fsync'd before the
/// append returns (write-ahead semantics — the journal always leads the
/// in-memory state).
#[derive(Debug)]
pub struct ServeJournal {
    path: PathBuf,
    file: std::fs::File,
    /// Set when a failed append could not be rolled back: the on-disk tail
    /// is a partial line, and appending more would corrupt the interior.
    poisoned: bool,
    /// Armed fault injections (tests; site `journal-append`).
    faults: FaultPlan,
}

impl ServeJournal {
    /// Open (or create) `dir/serve_journal.jsonl` for appending.  An
    /// existing journal whose final line is torn (crash mid-append) is
    /// truncated back to its last complete record first — otherwise the
    /// first record this session appends would concatenate onto the torn
    /// fragment and become unparseable *interior* corruption.
    pub fn open_append(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating journal dir {}: {e}", dir.display()))?;
        let path = dir.join(SERVE_JOURNAL_FILE);
        let existed = path.exists();
        if existed {
            truncate_torn_tail(&path)?;
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("opening serve journal {}: {e}", path.display()))?;
        if !existed {
            // the file's *existence* must survive power loss too, or the
            // first fsynced record could vanish with its directory entry
            fsync_dir(dir)
                .map_err(|e| anyhow::anyhow!("syncing journal dir {}: {e}", dir.display()))?;
        }
        Ok(Self {
            path,
            file,
            poisoned: false,
            faults: FaultPlan::none(),
        })
    }

    /// Arm fault injections on the append path (site `journal-append`,
    /// fired between the write and its fsync).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Where this journal lives.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record a job accepted via `submit`, with its full configuration.
    pub fn record_submitted(&mut self, job: &str, cfg: &SearchConfig) -> Result<()> {
        self.append(job, "submitted", vec![("config", cfg.to_checkpoint_json())])
    }

    /// Record a status transition (running / done / failed / cancelled).
    pub fn record_status(
        &mut self,
        job: &str,
        status: JobStatus,
        error: Option<&str>,
    ) -> Result<()> {
        let mut fields = vec![("status", Json::str(status.to_string()))];
        if let Some(e) = error {
            fields.push(("error", Json::str(e)));
        }
        self.append(job, "status", fields)
    }

    /// Record that a restarted service re-queued this interrupted job.
    pub fn record_resumed(&mut self, job: &str) -> Result<()> {
        self.append(job, "resumed", Vec::new())
    }

    fn append(&mut self, job: &str, event: &str, fields: Vec<(&str, Json)>) -> Result<()> {
        anyhow::ensure!(
            !self.poisoned,
            "serve journal {} may end in a partial line (an earlier failed \
             append could not be rolled back); refusing further appends",
            self.path.display()
        );
        let mut all = vec![
            ("schema_version", Json::num(SERVE_JOURNAL_SCHEMA_VERSION as f64)),
            ("kind", Json::str(JOURNAL_KIND)),
            ("job", Json::str(job)),
            ("event", Json::str(event)),
        ];
        all.extend(fields);
        let mut line = Json::obj(all).dump();
        line.push('\n');
        let len_before = self
            .file
            .metadata()
            .map(|m| m.len())
            .map_err(|e| anyhow::anyhow!("stat of {}: {e}", self.path.display()))?;
        let t0 = Instant::now();
        let written = self.write_and_sync(&line);
        obs_append_seconds().observe_duration(t0.elapsed());
        if let Err(e) = written {
            // a failed append may have left part of the line on disk; roll
            // back to the pre-append offset so later records cannot
            // concatenate onto it (interior corruption at the next replay)
            match self.file.set_len(len_before).and_then(|()| self.file.sync_data()) {
                Ok(()) => {}
                Err(te) => {
                    self.poisoned = true;
                    log::error!(
                        "serve journal {}: rollback of a failed append also failed \
                         ({te}); journal closed to further appends",
                        self.path.display()
                    );
                }
            }
            return Err(e);
        }
        Ok(())
    }

    fn write_and_sync(&mut self, line: &str) -> Result<()> {
        use std::io::Write as _;
        self.file
            .write_all(line.as_bytes())
            .map_err(|e| anyhow::anyhow!("appending to {}: {e}", self.path.display()))?;
        // fault site between write and fsync: the worst case — bytes may
        // have reached the disk, but the append must still report failure
        self.faults.trip("journal-append")?;
        // write-ahead: the record must be on disk before the transition is
        // acted on, or a crash could lose a job the client was promised
        self.file
            .sync_data()
            .map_err(|e| anyhow::anyhow!("syncing {}: {e}", self.path.display()))?;
        Ok(())
    }
}

/// Truncate `path` back to the end of its last complete (newline-terminated)
/// record, dropping a torn final line left by a crash mid-append.
fn truncate_torn_tail(path: &Path) -> Result<()> {
    let bytes =
        std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    if bytes.is_empty() || bytes.ends_with(b"\n") {
        return Ok(());
    }
    let keep = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |i| i + 1) as u64;
    log::warn!(
        "serve journal {}: dropping torn final line (crash mid-append): \
         truncating {} -> {keep} bytes",
        path.display(),
        bytes.len()
    );
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .map_err(|e| anyhow::anyhow!("opening {} for truncation: {e}", path.display()))?;
    f.set_len(keep)
        .map_err(|e| anyhow::anyhow!("truncating {}: {e}", path.display()))?;
    f.sync_data()
        .map_err(|e| anyhow::anyhow!("syncing {}: {e}", path.display()))?;
    Ok(())
}

/// A job reconstructed from the journal: last status wins.
#[derive(Clone, Debug)]
pub struct ReplayedJob {
    /// The job id (`job-<index>`, dense and ordered by submission).
    pub id: String,
    /// The submitted search configuration (checkpoint encoding, loss-free).
    pub cfg: SearchConfig,
    /// Last journaled status.
    pub status: JobStatus,
    /// Last journaled error payload, if the job failed.
    pub error: Option<String>,
}

/// Fold `dir`'s journal into per-job final states (empty when no journal
/// exists).  Interior corruption is a clean error naming the line; an
/// unparseable final line is tolerated with a warning (crash mid-append).
pub fn replay_journal(dir: &Path) -> Result<Vec<ReplayedJob>> {
    let path = dir.join(SERVE_JOURNAL_FILE);
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading serve journal {}: {e}", path.display()))?;
    let lines: Vec<(usize, &str)> = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .collect();
    let mut jobs: Vec<ReplayedJob> = Vec::new();
    for (pos, (lineno, line)) in lines.iter().enumerate() {
        let entry = match Json::parse(line) {
            Ok(j) => j,
            Err(e) if pos + 1 == lines.len() => {
                log::warn!(
                    "serve journal {}: ignoring truncated final line {} ({e})",
                    path.display(),
                    lineno + 1
                );
                break;
            }
            Err(e) => anyhow::bail!(
                "serve journal {} is corrupt at line {}: {e} — move the file aside to \
                 start fresh (interrupted jobs will be lost)",
                path.display(),
                lineno + 1
            ),
        };
        apply(&mut jobs, &entry).map_err(|e| {
            e.context(format!("serve journal {} line {}", path.display(), lineno + 1))
        })?;
    }
    obs_replayed().add(jobs.len() as u64);
    Ok(jobs)
}

fn apply(jobs: &mut Vec<ReplayedJob>, entry: &Json) -> Result<()> {
    anyhow::ensure!(
        entry.req_str("kind")? == JOURNAL_KIND,
        "not a serve journal entry"
    );
    anyhow::ensure!(
        entry.req_usize("schema_version")? == SERVE_JOURNAL_SCHEMA_VERSION,
        "journal schema version mismatch"
    );
    let job_id = entry.req_str("job")?;
    match entry.req_str("event")? {
        "submitted" => {
            let expect = format!("job-{}", jobs.len());
            anyhow::ensure!(
                job_id == expect,
                "expected submission of '{expect}', found '{job_id}' \
                 (job ids must be dense and in submission order)"
            );
            jobs.push(ReplayedJob {
                id: job_id.to_string(),
                cfg: SearchConfig::from_checkpoint_json(entry.req("config")?)?,
                status: JobStatus::Queued,
                error: None,
            });
        }
        "status" => {
            let job = find(jobs, job_id)?;
            job.status = entry.req_str("status")?.parse()?;
            job.error = entry.get("error").and_then(Json::as_str).map(str::to_string);
        }
        "resumed" => {
            // a later session re-queued the job; its status starts over
            let job = find(jobs, job_id)?;
            job.status = JobStatus::Queued;
            job.error = None;
        }
        other => anyhow::bail!("unknown journal event '{other}'"),
    }
    Ok(())
}

fn find<'a>(jobs: &'a mut [ReplayedJob], id: &str) -> Result<&'a mut ReplayedJob> {
    jobs.iter_mut()
        .find(|j| j.id == id)
        .ok_or_else(|| anyhow::anyhow!("event for unknown job '{id}' (no submission seen)"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::AgentKind;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("galen_journal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cfg() -> SearchConfig {
        SearchConfig::fast(AgentKind::Joint, 0.5)
    }

    #[test]
    fn roundtrip_last_status_wins() {
        let dir = tmp("roundtrip");
        {
            let mut j = ServeJournal::open_append(&dir).unwrap();
            j.record_submitted("job-0", &cfg()).unwrap();
            j.record_status("job-0", JobStatus::Running, None).unwrap();
            j.record_submitted("job-1", &cfg()).unwrap();
            j.record_status("job-0", JobStatus::Done, None).unwrap();
            j.record_status("job-1", JobStatus::Failed, Some("boom")).unwrap();
        }
        let jobs = replay_journal(&dir).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].status, JobStatus::Done);
        assert_eq!(jobs[0].error, None);
        assert_eq!(jobs[1].status, JobStatus::Failed);
        assert_eq!(jobs[1].error.as_deref(), Some("boom"));
        assert_eq!(
            jobs[0].cfg.to_checkpoint_json().dump(),
            cfg().to_checkpoint_json().dump(),
            "the submitted config must survive replay loss-free"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_journal_replays_empty() {
        assert!(replay_journal(&tmp("missing")).unwrap().is_empty());
    }

    #[test]
    fn interrupted_job_replays_as_non_terminal() {
        let dir = tmp("interrupted");
        {
            let mut j = ServeJournal::open_append(&dir).unwrap();
            j.record_submitted("job-0", &cfg()).unwrap();
            j.record_status("job-0", JobStatus::Running, None).unwrap();
        }
        let jobs = replay_journal(&dir).unwrap();
        assert!(!jobs[0].status.is_terminal(), "crashed mid-run: must be resumable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_final_line_is_tolerated() {
        let dir = tmp("truncated");
        {
            let mut j = ServeJournal::open_append(&dir).unwrap();
            j.record_submitted("job-0", &cfg()).unwrap();
            j.record_status("job-0", JobStatus::Running, None).unwrap();
        }
        // simulate a crash mid-append: half a status line at the tail
        let path = dir.join(SERVE_JOURNAL_FILE);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str(r#"{"schema_version":1,"kind":"galen_serve_jour"#);
        std::fs::write(&path, text).unwrap();
        let jobs = replay_journal(&dir).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].status, JobStatus::Running);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopening_truncates_torn_tail_before_appending() {
        let dir = tmp("torn_reopen");
        {
            let mut j = ServeJournal::open_append(&dir).unwrap();
            j.record_submitted("job-0", &cfg()).unwrap();
            j.record_status("job-0", JobStatus::Running, None).unwrap();
        }
        // crash mid-append: half a line, no trailing newline
        let path = dir.join(SERVE_JOURNAL_FILE);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str(r#"{"schema_version":1,"kind":"galen_serve_jour"#);
        std::fs::write(&path, &text).unwrap();
        // the resumed session appends over the torn tail...
        {
            let mut j = ServeJournal::open_append(&dir).unwrap();
            j.record_resumed("job-0").unwrap();
            j.record_status("job-0", JobStatus::Done, None).unwrap();
        }
        // ...and the *next* restart still replays cleanly: the fragment was
        // truncated, not fused into an unparseable interior line
        let jobs = replay_journal(&dir).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].status, JobStatus::Done);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_append_rolls_back_partial_line() {
        let dir = tmp("rollback");
        let mut j = ServeJournal::open_append(&dir)
            .unwrap()
            .with_faults(FaultPlan::parse("journal-append:1:io-error").unwrap());
        // the fault fires after the bytes are written: the append must
        // report failure AND leave no partial line behind
        let err = j.record_submitted("job-0", &cfg()).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        let raw = std::fs::read_to_string(dir.join(SERVE_JOURNAL_FILE)).unwrap();
        assert!(raw.is_empty(), "rolled-back append left bytes: {raw:?}");
        // the journal stays usable and the job id can be reused — replay's
        // dense-id invariant holds
        j.record_submitted("job-0", &cfg()).unwrap();
        j.record_status("job-0", JobStatus::Done, None).unwrap();
        drop(j);
        let jobs = replay_journal(&dir).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].status, JobStatus::Done);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interior_corruption_is_a_clean_error() {
        let dir = tmp("interior");
        {
            let mut j = ServeJournal::open_append(&dir).unwrap();
            j.record_submitted("job-0", &cfg()).unwrap();
        }
        let path = dir.join(SERVE_JOURNAL_FILE);
        let mut text = "not json at all\n".to_string();
        text.push_str(&std::fs::read_to_string(&path).unwrap());
        std::fs::write(&path, text).unwrap();
        let err = format!("{:#}", replay_journal(&dir).unwrap_err());
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("corrupt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_and_order_violations_are_rejected() {
        let dir = tmp("violations");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(SERVE_JOURNAL_FILE);

        std::fs::write(
            &path,
            "{\"schema_version\":999,\"kind\":\"galen_serve_journal\",\"job\":\"job-0\",\"event\":\"resumed\"}\n",
        )
        .unwrap();
        let err = format!("{:#}", replay_journal(&dir).unwrap_err());
        assert!(err.contains("schema"), "{err}");

        // a status line for a job that was never submitted
        std::fs::write(
            &path,
            "{\"schema_version\":1,\"kind\":\"galen_serve_journal\",\"job\":\"job-3\",\"event\":\"status\",\"status\":\"done\"}\nx\n",
        )
        .unwrap();
        let err = format!("{:#}", replay_journal(&dir).unwrap_err());
        assert!(err.contains("unknown job"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
