//! Experiment coordinator: wires runtime + evaluator + simulator + search
//! into the paper's experiment protocols and persists results.
//!
//! * `Session` — owns the PJRT evaluator, latency simulator and sensitivity
//!   table for one model variant / hardware target.
//! * `search` / `sweep` — single searches and target-rate sweeps (Table 1,
//!   Figures 3-4).
//! * `sequential` — the appendix's prune-then-quantize / quantize-then-prune
//!   schemes (Figure 5).
//! * `serve` — the long-running JSONL job service (`galen serve`):
//!   submit/status/events/result/cancel over stdin/stdout, many concurrent
//!   search jobs multiplexed over a worker pool with shared latency caches.
//! * `net` — the socket front (`galen serve --listen`): the same protocol
//!   over TCP or Unix-socket connections, thread-per-connection with a
//!   versioned `hello` handshake and bounded admission.
//! * `journal` — durable write-ahead job journal behind
//!   `galen serve --resume-jobs` crash recovery.
//! * result records are serialized to `results/*.json` for EXPERIMENTS.md.

mod journal;
mod net;
mod report;
mod service;
mod session;

pub use journal::{
    replay_journal, ReplayedJob, ServeJournal, SERVE_JOURNAL_FILE, SERVE_JOURNAL_SCHEMA_VERSION,
};
pub use net::{serve_listener, BoundListener, NetOptions};
pub use report::{policy_json, policy_report, table1_header, ExperimentRecord};
pub use service::{
    serve, JobStatus, ServeOptions, ServeStats, MAX_REQUEST_LINE, SERVE_PROTOCOL_VERSION,
};
pub use session::{Backend, Packager, Session, SessionOptions};
