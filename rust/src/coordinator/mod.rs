//! Experiment coordinator: wires runtime + evaluator + simulator + search
//! into the paper's experiment protocols and persists results.
//!
//! * `Session` — owns the PJRT evaluator, latency simulator and sensitivity
//!   table for one model variant / hardware target.
//! * `search` / `sweep` — single searches and target-rate sweeps (Table 1,
//!   Figures 3-4).
//! * `sequential` — the appendix's prune-then-quantize / quantize-then-prune
//!   schemes (Figure 5).
//! * result records are serialized to `results/*.json` for EXPERIMENTS.md.

mod report;
mod session;

pub use report::{policy_json, policy_report, table1_header, ExperimentRecord};
pub use session::{Backend, Session, SessionOptions};
