//! A coordinator session: one model variant on one hardware target.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::agent::{mapper_for, AgentKind, PruningMapper, QuantizationMapper};
use crate::artifact;
use crate::compress::DiscretePolicy;
use crate::eval::{Evaluator, SensitivityConfig, SensitivityTable, Split};
use crate::hw::{
    CostModel, HwTarget, HybridProvider, LatencyKind, LatencyProvider, LatencySimulator,
    MeasuredProfiler, ProfilerConfig,
};
use crate::model::ModelIr;
use crate::runtime::{ArtifactRegistry, PjrtRuntime};
use crate::search::{
    run_search, run_sweep, LatencyFactory, PolicyEvaluator, SearchConfig, SearchOutcome,
    SimEvaluator, SweepGrid, SweepReport,
};

/// Accuracy backend for searches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Real model accuracy through the PJRT forward artifact.
    Pjrt,
    /// Synthetic accuracy model (simulator-only studies / tests).
    Synthetic,
}

/// Parses the CLI labels `pjrt`/`synthetic` (with the aliases
/// `real`/`sim`) — the inverse of the `Display` labels.
impl std::str::FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pjrt" | "real" => Ok(Self::Pjrt),
            "synthetic" | "sim" => Ok(Self::Synthetic),
            other => anyhow::bail!("unknown accuracy backend '{other}' (pjrt|synthetic)"),
        }
    }
}

/// Stable lowercase label (CLI, logs); honors format padding.
impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(match self {
            Self::Pjrt => "pjrt",
            Self::Synthetic => "synthetic",
        })
    }
}

/// Everything configurable about a session, with sensible defaults from
/// `SessionOptions::new`.
#[derive(Clone, Debug)]
pub struct SessionOptions {
    /// Where the AOT artifacts (`meta_*.json`, HLO text, weights) live.
    pub artifacts_dir: PathBuf,
    /// Model variant (`micro`/`resnet18s`/`resnet18`).
    pub variant: String,
    /// The hardware target policies are scored against.
    pub target_hw: HwTarget,
    /// Accuracy backend (real PJRT artifact or synthetic model).
    pub backend: Backend,
    /// Latency backend searches score policies with (`--latency`).
    pub latency: LatencyKind,
    /// Measurement-harness knobs for the measured/hybrid backends.
    pub profiler: ProfilerConfig,
    /// Root of the on-disk profile caches (`<dir>/<target>/<model>.json`);
    /// None keeps measured profiles in memory only (tests).
    pub profiles_dir: Option<PathBuf>,
    /// Sensitivity-analysis probe grid (Figure 6).
    pub sensitivity: SensitivityConfig,
    /// Cache file for the sensitivity table (skipped when None).
    pub sensitivity_cache: Option<PathBuf>,
    /// Session seed (forked per subsystem).
    pub seed: u64,
}

impl SessionOptions {
    /// Defaults for `variant`: PJRT accuracy, Cortex-A72 target, simulator
    /// latency, repo-level artifact/profile/result directories.
    pub fn new(variant: &str) -> Self {
        Self {
            artifacts_dir: crate::artifacts_dir(),
            variant: variant.to_string(),
            target_hw: HwTarget::cortex_a72(),
            backend: Backend::Pjrt,
            latency: LatencyKind::Sim,
            profiler: ProfilerConfig::default(),
            profiles_dir: Some(crate::profiles_dir()),
            sensitivity: SensitivityConfig::default(),
            sensitivity_cache: Some(
                crate::results_dir().join(format!("sensitivity_{variant}.json")),
            ),
            seed: 7,
        }
    }
}

/// Owns everything a search needs.
pub struct Session {
    /// The options the session was opened with.
    pub opts: SessionOptions,
    /// Structural model description (layer shapes, wiring, policy inputs).
    pub ir: ModelIr,
    /// Present iff backend == Pjrt.
    pub evaluator: Option<Evaluator>,
    /// The upfront layer-sensitivity table (state features).
    pub sens: SensitivityTable,
}

impl Session {
    /// Bring up the session: PJRT client, artifacts, upfront sensitivity.
    pub fn open(opts: SessionOptions) -> Result<Self> {
        match opts.backend {
            Backend::Pjrt => {
                let runtime = PjrtRuntime::cpu()?;
                let reg = ArtifactRegistry::load(&runtime, &opts.artifacts_dir, &opts.variant)?;
                let ir = reg.ir.clone();
                let evaluator = Evaluator::new(runtime, reg)?;
                let sens = match &opts.sensitivity_cache {
                    Some(path) => {
                        SensitivityTable::compute_cached(&evaluator, &opts.sensitivity, path)?
                    }
                    None => SensitivityTable::compute(&evaluator, &opts.sensitivity)?,
                };
                Ok(Self {
                    opts,
                    ir,
                    evaluator: Some(evaluator),
                    sens,
                })
            }
            Backend::Synthetic => {
                // Synthetic sessions only need the structural manifest.  An
                // artifact manifest wins when present (it carries the
                // trained base accuracy); otherwise the built-in model zoo
                // constructs it in-process, so `--synthetic` sessions (and
                // sweeps, serve, tests) never require `aot.py` to have run.
                let path = opts.artifacts_dir.join(format!("meta_{}.json", opts.variant));
                let meta = if path.exists() {
                    crate::model::load_meta(&path)?
                } else if crate::model::zoo::has_variant(&opts.variant) {
                    log::info!(
                        "no artifact manifest at {}; using the built-in zoo manifest for '{}'",
                        path.display(),
                        opts.variant
                    );
                    crate::model::zoo::meta(&opts.variant)?
                } else {
                    anyhow::bail!(
                        "variant '{}' has neither an artifact manifest ({}) nor a zoo \
                         definition (built-in: {})",
                        opts.variant,
                        path.display(),
                        crate::model::zoo::VARIANTS.join(", ")
                    );
                };
                let ir = ModelIr::from_meta(&meta)?;
                let sens = SensitivityTable::disabled(
                    ir.layers.len(),
                    &opts.sensitivity,
                    &opts.variant,
                );
                Ok(Self {
                    opts,
                    ir,
                    evaluator: None,
                    sens,
                })
            }
        }
    }

    /// Synthetic session straight from an in-memory manifest (tests).
    pub fn synthetic(ir: ModelIr, opts: SessionOptions) -> Self {
        let sens =
            SensitivityTable::disabled(ir.layers.len(), &opts.sensitivity, &opts.variant);
        Self {
            opts,
            ir,
            evaluator: None,
            sens,
        }
    }

    /// An artifact-free session over the in-code tiny fixture IR:
    /// synthetic accuracy, fast profiler settings, no on-disk caches.
    /// What every `--fixture` mode (`galen serve`, the example smoke
    /// runs) builds on, so the fixture wiring lives in exactly one place.
    pub fn fixture(latency: LatencyKind, seed: u64) -> Result<Self> {
        let ir = ModelIr::from_meta(&crate::model::ir::test_fixtures::tiny_meta())?;
        let mut opts = SessionOptions::new("tiny");
        opts.backend = Backend::Synthetic;
        opts.latency = latency;
        opts.seed = seed;
        opts.sensitivity_cache = None;
        opts.profiles_dir = None; // keep fixture runs artifact-free on disk
        opts.profiler = ProfilerConfig::fast();
        Ok(Self::synthetic(ir, opts))
    }

    /// An analytical latency simulator for this session's target.
    pub fn simulator(&self, seed: u64) -> LatencySimulator {
        LatencySimulator::new(CostModel::new(self.opts.target_hw.clone()), seed)
    }

    /// A measured-kernel profiler for this session's target and model,
    /// disk-backed when `opts.profiles_dir` is set.
    pub fn profiler(&self) -> Result<MeasuredProfiler> {
        let cfg = self.opts.profiler.clone();
        match &self.opts.profiles_dir {
            Some(dir) => MeasuredProfiler::with_cache(
                self.opts.target_hw.clone(),
                &self.opts.variant,
                cfg,
                dir,
            ),
            None => Ok(MeasuredProfiler::new(
                self.opts.target_hw.clone(),
                &self.opts.variant,
                cfg,
            )),
        }
    }

    /// The latency backend of this session's searches (`opts.latency`).
    /// Hybrid providers are calibrated against the default probe set before
    /// being returned.
    pub fn latency_provider(&self, seed: u64) -> Result<Box<dyn LatencyProvider>> {
        match self.opts.latency {
            LatencyKind::Sim => Ok(Box::new(self.simulator(seed))),
            LatencyKind::Measured => Ok(Box::new(self.profiler()?)),
            LatencyKind::Hybrid => {
                let mut hybrid = HybridProvider::new(self.profiler()?, self.simulator(seed));
                hybrid.calibrate_default(&self.ir);
                Ok(Box::new(hybrid))
            }
        }
    }

    fn policy_evaluator<'a>(
        &'a self,
        cfg: &SearchConfig,
    ) -> Box<dyn PolicyEvaluator + 'a> {
        match (&self.evaluator, self.opts.backend) {
            (Some(ev), Backend::Pjrt) => Box::new((ev, Split::Val, cfg.eval_batches)),
            _ => Box::new(SimEvaluator::new(&self.ir)),
        }
    }

    /// Run one policy search.
    pub fn search(&self, cfg: &SearchConfig) -> Result<SearchOutcome> {
        self.search_from(cfg, None, None)
    }

    /// Run one policy search from an optional base policy with an optional
    /// sensitivity-table override (T2/F7 ablation passes `disabled`).
    pub fn search_from(
        &self,
        cfg: &SearchConfig,
        base: Option<&DiscretePolicy>,
        sens_override: Option<&SensitivityTable>,
    ) -> Result<SearchOutcome> {
        let mapper = mapper_for(cfg.agent);
        let ev = self.policy_evaluator(cfg);
        let mut provider = self.latency_provider(cfg.seed ^ 0x5117)?;
        let out = run_search(
            &self.ir,
            sens_override.unwrap_or(&self.sens),
            ev.as_ref(),
            provider.as_mut(),
            mapper.as_ref(),
            cfg,
            base,
        )?;
        provider.persist()?;
        Ok(out)
    }

    /// Sweep target compression rates for one agent (Figure 4 series),
    /// sequentially, with this session's full accuracy backend.  For grids
    /// across agents *and* targets, prefer `sweep_parallel`.
    pub fn sweep(
        &self,
        agent: AgentKind,
        targets: &[f64],
        proto: &SearchConfig,
    ) -> Result<Vec<SearchOutcome>> {
        let mut out = Vec::with_capacity(targets.len());
        for &c in targets {
            let mut cfg = proto.clone();
            cfg.agent = agent;
            cfg.target = c;
            out.push(self.search(&cfg)?);
        }
        Ok(out)
    }

    /// A latency-provider factory for this session's backend whose
    /// providers share cross-worker caches (`search::LatencyFactory`) —
    /// what `sweep_parallel` hands to each worker.
    pub fn latency_factory(&self) -> LatencyFactory {
        LatencyFactory::new(
            self.opts.latency,
            self.opts.target_hw.clone(),
            &self.opts.variant,
            self.opts.profiler.clone(),
            self.opts.profiles_dir.clone(),
        )
    }

    /// Run the sweep grid in parallel on `workers` threads (0 = all cores)
    /// and fold the outcomes into a Pareto front.
    ///
    /// Jobs are deterministically seeded from `proto.seed` per
    /// `(agent, target, replicate)` cell, so with the simulator latency
    /// backend the result is bit-identical for every worker count (the
    /// measured/hybrid backends are consistent within one sweep but carry
    /// run-to-run timing jitter).  Accuracy is the deterministic synthetic
    /// proxy (`search::SimEvaluator`) regardless of this session's
    /// accuracy backend — the PJRT evaluator is not thread-safe; validate
    /// chosen front points afterwards via `search`/`validate`.  Latency
    /// uses this session's `opts.latency` backend with shared caches, so
    /// concurrent workers reuse each other's measurements.
    pub fn sweep_parallel(
        &self,
        grid: &SweepGrid,
        proto: &SearchConfig,
        workers: usize,
    ) -> Result<SweepReport> {
        run_sweep(&self.ir, &self.sens, grid, proto, workers, &self.latency_factory())
    }

    /// Persist a sweep's Pareto front to `dir/<target>/<model>.json`
    /// (see `search::ParetoFront::save`); returns the path written.
    pub fn save_sweep(&self, report: &SweepReport, dir: &Path) -> Result<PathBuf> {
        report
            .front
            .save(dir, &self.opts.target_hw.name, &self.opts.variant)
    }

    /// Resolve the weight tensors to package, with a provenance label: the
    /// AOT-exported `weights_<variant>.gten` when present, otherwise the
    /// deterministic synthetic fallback (`artifact::synthetic_weights`).
    pub fn packaging_weights(&self) -> Result<(artifact::WeightMap, String)> {
        let path = self
            .opts
            .artifacts_dir
            .join(format!("weights_{}.gten", self.opts.variant));
        if path.exists() {
            let file = crate::util::gten::read(&path)?;
            let mut map = artifact::WeightMap::new();
            for (name, t) in file {
                // packaging consumes only the conv/linear weight tensors;
                // BN stats etc. stay in the AOT artifact
                if !name.ends_with(".w") {
                    continue;
                }
                if let crate::util::gten::GtenData::F32(data) = t.data {
                    map.insert(name, (t.shape, data));
                }
            }
            Ok((map, format!("gten:{}", path.display())))
        } else {
            Ok((
                artifact::synthetic_weights(&self.ir),
                format!(
                    "synthetic:{:016x}",
                    artifact::pack::synthetic_seed(&self.ir.variant)
                ),
            ))
        }
    }

    /// The profile-cache provenance label artifact manifests record.
    fn profile_cache_label(&self) -> String {
        match &self.opts.profiles_dir {
            Some(d) => d.display().to_string(),
            None => "none".to_string(),
        }
    }

    /// Package a finished search outcome into
    /// `root/<sanitized target>/<variant>-<policyhash>.galen` (written
    /// atomically) and return the path.  With `hmac_key`, the manifest is
    /// signed so consumers can detect tampered latency claims.
    pub fn package_outcome(
        &self,
        outcome: &SearchOutcome,
        root: &Path,
        hmac_key: Option<&[u8]>,
    ) -> Result<PathBuf> {
        let (weights, weights_source) = self.packaging_weights()?;
        let claim = artifact::LatencyClaim {
            latency_s: outcome.best.latency_s,
            base_latency_s: outcome.base_latency_s,
            backend: outcome.latency_backend.clone(),
        };
        self.package(&outcome.best_policy, claim, &weights, weights_source, root, hmac_key)
    }

    /// Package an explicit policy + latency claim (the building block of
    /// [`Session::package_outcome`]; `galen package` uses this directly so
    /// it can rebuild the claim from a persisted experiment record).
    pub fn package(
        &self,
        policy: &DiscretePolicy,
        claim: artifact::LatencyClaim,
        weights: &artifact::WeightMap,
        weights_source: String,
        root: &Path,
        hmac_key: Option<&[u8]>,
    ) -> Result<PathBuf> {
        let art = artifact::pack(&artifact::PackInputs {
            ir: &self.ir,
            policy,
            weights,
            weights_source,
            target: &self.opts.target_hw,
            claim,
            profile_cache: self.profile_cache_label(),
        })?;
        let path = artifact::artifact_path(root, &self.opts.target_hw, &self.opts.variant, policy);
        art.write(&path, hmac_key)?;
        Ok(path)
    }

    /// A thread-safe packaging callback for `galen serve`: captures
    /// everything it needs by value (IR, target, resolved weights), so
    /// workers can package terminal jobs without touching the session.
    pub fn packager(&self, root: PathBuf, hmac_key: Option<Vec<u8>>) -> Result<Packager> {
        let (weights, weights_source) = self.packaging_weights()?;
        let ir = self.ir.clone();
        let target = self.opts.target_hw.clone();
        let variant = self.opts.variant.clone();
        let profile_cache = self.profile_cache_label();
        Ok(Packager::new(move |outcome: &SearchOutcome| {
            let art = artifact::pack(&artifact::PackInputs {
                ir: &ir,
                policy: &outcome.best_policy,
                weights: &weights,
                weights_source: weights_source.clone(),
                target: &target,
                claim: artifact::LatencyClaim {
                    latency_s: outcome.best.latency_s,
                    base_latency_s: outcome.base_latency_s,
                    backend: outcome.latency_backend.clone(),
                },
                profile_cache: profile_cache.clone(),
            })?;
            let path = artifact::artifact_path(&root, &target, &variant, &outcome.best_policy);
            art.write(&path, hmac_key.as_deref())?;
            Ok(path)
        }))
    }

    /// Sequential two-stage search (appendix, Figure 5): run `first` to the
    /// intermediate target c1 = (1 + c) / 2, freeze its policy, then run the
    /// other method to the final target c.
    pub fn sequential(
        &self,
        first: AgentKind,
        target: f64,
        proto: &SearchConfig,
    ) -> Result<(SearchOutcome, SearchOutcome)> {
        anyhow::ensure!(
            first != AgentKind::Joint,
            "sequential schemes combine the two single-method agents"
        );
        let c1 = (1.0 + target) / 2.0;
        let mut cfg1 = proto.clone();
        cfg1.agent = first;
        cfg1.target = c1;
        // paper appendix: the pruning runs use the joint agent's channel
        // rounding so the downstream quantization stays MIX-compatible
        let ev = self.policy_evaluator(&cfg1);
        let mut provider = self.latency_provider(cfg1.seed ^ 0x5117)?;
        let first_mapper: Box<dyn crate::agent::PolicyMapper> = match first {
            AgentKind::Pruning => Box::new(PruningMapper::rounded()),
            AgentKind::Quantization => Box::new(QuantizationMapper::default()),
            AgentKind::Joint => unreachable!(),
        };
        let out1 = run_search(
            &self.ir,
            &self.sens,
            ev.as_ref(),
            provider.as_mut(),
            first_mapper.as_ref(),
            &cfg1,
            None,
        )?;
        provider.persist()?;

        let second = match first {
            AgentKind::Pruning => AgentKind::Quantization,
            AgentKind::Quantization => AgentKind::Pruning,
            AgentKind::Joint => unreachable!(),
        };
        let mut cfg2 = proto.clone();
        cfg2.agent = second;
        cfg2.target = target;
        cfg2.seed = proto.seed.wrapping_add(1);
        let second_mapper: Box<dyn crate::agent::PolicyMapper> = match second {
            AgentKind::Pruning => Box::new(PruningMapper::rounded()),
            AgentKind::Quantization => Box::new(QuantizationMapper::default()),
            AgentKind::Joint => unreachable!(),
        };
        let ev2 = self.policy_evaluator(&cfg2);
        let mut provider2 = self.latency_provider(cfg2.seed ^ 0x5117)?;
        let out2 = run_search(
            &self.ir,
            &self.sens,
            ev2.as_ref(),
            provider2.as_mut(),
            second_mapper.as_ref(),
            &cfg2,
            Some(&out1.best_policy),
        )?;
        provider2.persist()?;
        Ok((out1, out2))
    }
}

/// A thread-safe callback that packages a finished search outcome into a
/// `.galen` artifact and returns the path written.  Built by
/// [`Session::packager`] and handed to `galen serve`
/// (`ServeOptions::packager`) so workers can package terminal jobs.
#[derive(Clone)]
pub struct Packager(
    std::sync::Arc<dyn Fn(&SearchOutcome) -> Result<PathBuf> + Send + Sync>,
);

impl Packager {
    /// Wrap a packaging closure.
    pub fn new(f: impl Fn(&SearchOutcome) -> Result<PathBuf> + Send + Sync + 'static) -> Self {
        Self(std::sync::Arc::new(f))
    }

    /// Package `outcome`, returning the artifact path written.
    pub fn package(&self, outcome: &SearchOutcome) -> Result<PathBuf> {
        (self.0)(outcome)
    }
}

impl std::fmt::Debug for Packager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Packager(..)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::DdpgConfig;
    use crate::model::ir::test_fixtures::tiny_meta;

    fn session() -> Session {
        let ir = ModelIr::from_meta(&tiny_meta()).unwrap();
        let mut opts = SessionOptions::new("tiny");
        opts.backend = Backend::Synthetic;
        opts.sensitivity_cache = None;
        opts.profiles_dir = None; // tests must not write repo-level caches
        opts.profiler = ProfilerConfig::fast();
        Session::synthetic(ir, opts)
    }

    fn fast(agent: AgentKind, c: f64) -> SearchConfig {
        let mut cfg = SearchConfig::fast(agent, c);
        cfg.episodes = 24;
        cfg.warmup_episodes = 6;
        cfg.log_every = 0;
        cfg.ddpg = DdpgConfig {
            hidden: (32, 24),
            batch: 24,
            replay_capacity: 400,
            ..Default::default()
        };
        cfg
    }

    /// Synthetic sessions fall back to the zoo when artifacts are absent —
    /// `galen search --synthetic --variant mobilenetv2s` end to end.
    #[test]
    fn synthetic_session_opens_zoo_variants_without_artifacts() {
        let mut opts = SessionOptions::new("mobilenetv2s");
        // point at a directory that cannot hold artifacts
        opts.artifacts_dir = std::env::temp_dir().join(format!(
            "galen_no_artifacts_{}",
            std::process::id()
        ));
        opts.backend = Backend::Synthetic;
        opts.sensitivity_cache = None;
        opts.profiles_dir = None;
        opts.profiler = ProfilerConfig::fast();
        let s = Session::open(opts).unwrap();
        assert_eq!(s.ir.variant, "mobilenetv2s");
        assert!(s.ir.layers.iter().any(|l| l.depthwise));
        let mut cfg = fast(AgentKind::Joint, 0.5);
        cfg.episodes = 6;
        cfg.warmup_episodes = 2;
        let out = s.search(&cfg).unwrap();
        assert_eq!(out.history.len(), 6);
        assert!(out.best.latency_s > 0.0);

        // unknown variants still fail loudly, listing the zoo
        let mut opts = SessionOptions::new("resnet9000");
        opts.artifacts_dir =
            std::env::temp_dir().join(format!("galen_no_artifacts_{}", std::process::id()));
        opts.backend = Backend::Synthetic;
        let err = Session::open(opts).err().expect("unknown variant");
        assert!(format!("{err:#}").contains("mobilenetv2s"));
    }

    #[test]
    fn backend_parse_display_roundtrip() {
        assert_eq!("pjrt".parse::<Backend>().unwrap(), Backend::Pjrt);
        assert_eq!("synthetic".parse::<Backend>().unwrap(), Backend::Synthetic);
        assert!("nope".parse::<Backend>().is_err());
        for b in [Backend::Pjrt, Backend::Synthetic] {
            assert_eq!(b.to_string().parse::<Backend>().unwrap(), b);
        }
    }

    #[test]
    fn synthetic_search_runs() {
        let s = session();
        let out = s.search(&fast(AgentKind::Joint, 0.5)).unwrap();
        assert_eq!(out.history.len(), 24);
        assert!(out.best.latency_s > 0.0);
        assert_eq!(out.latency_backend, "sim");
    }

    #[test]
    fn measured_and_hybrid_backends_run_searches() {
        let mut s = session();
        s.opts.latency = LatencyKind::Measured;
        let mut cfg = fast(AgentKind::Quantization, 0.5);
        cfg.episodes = 6;
        cfg.warmup_episodes = 2;
        let out = s.search(&cfg).unwrap();
        assert_eq!(out.latency_backend, "measured");
        assert!(out.best.latency_s > 0.0);

        s.opts.latency = LatencyKind::Hybrid;
        let out = s.search(&cfg).unwrap();
        assert_eq!(out.latency_backend, "hybrid");
        assert!(out.best.latency_s > 0.0);
    }

    #[test]
    fn package_outcome_writes_a_loadable_artifact() {
        let s = session();
        let out = s.search(&fast(AgentKind::Joint, 0.5)).unwrap();
        let root = std::env::temp_dir().join(format!("galen_pkg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let path = s.package_outcome(&out, &root, None).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let loaded = crate::artifact::load(&path).unwrap();
        assert_eq!(loaded.manifest.variant, "tiny");
        assert_eq!(loaded.manifest.claim.latency_s, out.best.latency_s);
        assert!(loaded
            .manifest
            .provenance
            .weights
            .starts_with("synthetic:"));
        crate::artifact::check_against_ir(&loaded, &s.ir).unwrap();
        // the serve-path packager writes byte-identical output
        let p2 = s.packager(root.clone(), None).unwrap().package(&out).unwrap();
        assert_eq!(p2, path, "same policy -> same content-addressed path");
        assert_eq!(std::fs::read(&p2).unwrap(), bytes);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sweep_produces_one_outcome_per_target() {
        let s = session();
        let outs = s
            .sweep(AgentKind::Quantization, &[0.4, 0.6], &fast(AgentKind::Quantization, 0.4))
            .unwrap();
        assert_eq!(outs.len(), 2);
    }

    #[test]
    fn sweep_parallel_is_worker_count_invariant() {
        let s = session();
        let grid = SweepGrid::new(vec![AgentKind::Quantization], vec![0.4, 0.6]);
        let mut proto = fast(AgentKind::Quantization, 0.4);
        proto.episodes = 8;
        proto.warmup_episodes = 3;
        let seq = s.sweep_parallel(&grid, &proto, 1).unwrap();
        let par = s.sweep_parallel(&grid, &proto, 2).unwrap();
        assert_eq!(seq.outcomes.len(), 2);
        assert_eq!(seq.front, par.front);
        assert!(!seq.front.points.is_empty());
    }

    #[test]
    fn sequential_freezes_first_stage() {
        let s = session();
        let (first, second) = s
            .sequential(AgentKind::Pruning, 0.4, &fast(AgentKind::Pruning, 0.4))
            .unwrap();
        // second stage keeps the first stage's pruning decisions
        for l in &s.ir.layers {
            assert_eq!(
                second.best_policy.layers[l.index].kept_channels,
                first.best_policy.layers[l.index].kept_channels,
                "layer {}",
                l.name
            );
        }
        // and adds quantization on top
        let (_, int8, fp32) = crate::search::quant_histogram(&second.best_policy);
        assert!(int8 + fp32 == s.ir.layers.len());
    }

    #[test]
    fn sequential_rejects_joint_first() {
        let s = session();
        assert!(s
            .sequential(AgentKind::Joint, 0.4, &fast(AgentKind::Joint, 0.4))
            .is_err());
    }
}
