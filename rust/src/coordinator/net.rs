//! Socket front for the serve job service: `galen serve --listen <addr>`.
//!
//! Accepts TCP (`host:port`) or Unix-socket (`unix:<path>`) connections
//! and runs the same transport-agnostic [`super::service`] protocol loop
//! for each one, thread-per-connection, over one shared job pool — the
//! conformance suite asserts the wire behavior is byte-identical to the
//! stdio path.  Every socket connection must open with a successful
//! `hello` handshake (see the service module docs) before any other op.
//!
//! # Admission and drain
//!
//! Connections above [`NetOptions::max_connections`] receive exactly one
//! structured `ok:false` line carrying `retry_after_ms`, then the socket
//! closes — the accept loop itself never stalls on an overloaded pool.
//! When any client sends `shutdown`, the listener stops accepting, every
//! connection's next (possibly timed-out) read observes the drain flag and
//! closes, in-flight jobs finish or checkpoint, and each transition is
//! journaled exactly as on the stdio path.  A connection dying mid-request
//! is that client's problem: the error is logged, its jobs keep running,
//! and the service keeps serving everyone else.

use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use anyhow::Result;

use super::service::{
    obs_admission_rejected, protocol_loop, serve_with_front, ConnCtx, ServeOptions, ServeStats,
    ServiceState,
};
use crate::eval::SensitivityTable;
use crate::model::ModelIr;
use crate::search::LatencyFactory;
use crate::util::json::Json;

/// Knobs of the socket front.
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// Concurrent client connections admitted (0 = unlimited); excess
    /// connections get one structured rejection line and are closed.
    pub max_connections: usize,
}

/// 64 concurrent connections — far above a sharded sweep's client count,
/// low enough that a reconnect storm cannot exhaust threads.
impl Default for NetOptions {
    fn default() -> Self {
        Self { max_connections: 64 }
    }
}

/// How often blocked reads and idle accept polls re-check the drain flag:
/// the bound on how long shutdown waits for parked connections.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

/// A bound serve listener, ready for [`serve_listener`].
pub enum BoundListener {
    /// A TCP listener (`host:port`, port 0 picks a free one).
    Tcp(TcpListener),
    /// A Unix-domain socket listener and the path it is bound to (removed
    /// again when the listener drops).
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl BoundListener {
    /// Bind `spec`: `unix:<path>` for a Unix-domain socket, anything else
    /// as a TCP address.  A stale socket file left by a crashed serve is
    /// removed before binding (a live server holds the listener, so its
    /// file is never "stale").
    pub fn bind(spec: &str) -> Result<Self> {
        if let Some(path) = spec.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let path = PathBuf::from(path);
                if path.exists() {
                    std::fs::remove_file(&path).map_err(|e| {
                        anyhow::anyhow!("removing stale socket {}: {e}", path.display())
                    })?;
                }
                let listener = UnixListener::bind(&path).map_err(|e| {
                    anyhow::anyhow!("binding unix socket {}: {e}", path.display())
                })?;
                return Ok(Self::Unix(listener, path));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                anyhow::bail!("unix sockets are not supported on this platform ('{spec}')");
            }
        }
        let listener = TcpListener::bind(spec)
            .map_err(|e| anyhow::anyhow!("binding tcp {spec}: {e}"))?;
        Ok(Self::Tcp(listener))
    }

    /// The bound address, in the same form `bind` accepts — with port 0
    /// the caller needs this to learn the ephemeral port it actually got.
    pub fn local_addr(&self) -> String {
        match self {
            Self::Tcp(listener) => listener
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:?".to_string()),
            #[cfg(unix)]
            Self::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }
}

impl Drop for BoundListener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Self::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// One accepted client stream.  Both socket types clone into an owned
/// reader half (the writer keeps the original) and take a read timeout so
/// a parked connection re-checks the drain flag every [`POLL_INTERVAL`].
trait Conn: Read + Write + Send + Sized + 'static {
    /// Metric label (closed set: `tcp` | `unix`).
    const TRANSPORT: &'static str;

    /// An independently-owned handle to the same stream, for the read half.
    fn split(&self) -> std::io::Result<Self>;

    /// Blocking mode + read timeout (accepted sockets can inherit the
    /// listener's non-blocking flag on some platforms).
    fn configure(&self) -> std::io::Result<()>;
}

impl Conn for TcpStream {
    const TRANSPORT: &'static str = "tcp";

    fn split(&self) -> std::io::Result<Self> {
        self.try_clone()
    }

    fn configure(&self) -> std::io::Result<()> {
        self.set_nonblocking(false)?;
        self.set_read_timeout(Some(POLL_INTERVAL))?;
        // request/response lines are small; never trade latency for batching
        self.set_nodelay(true)
    }
}

#[cfg(unix)]
impl Conn for UnixStream {
    const TRANSPORT: &'static str = "unix";

    fn split(&self) -> std::io::Result<Self> {
        self.try_clone()
    }

    fn configure(&self) -> std::io::Result<()> {
        self.set_nonblocking(false)?;
        self.set_read_timeout(Some(POLL_INTERVAL))
    }
}

/// Run the job service behind a socket listener until a client sends
/// `shutdown`, then drain and return the run's counters — the networked
/// sibling of [`super::serve`], sharing its worker pool, journal and
/// checkpoint machinery via the same service core.
pub fn serve_listener(
    ir: &ModelIr,
    sens: &SensitivityTable,
    factory: &LatencyFactory,
    variant: &str,
    opts: &ServeOptions,
    net: &NetOptions,
    listener: BoundListener,
) -> Result<ServeStats> {
    serve_with_front(ir, sens, factory, variant, opts, |svc| {
        log::info!("serve: listening on {}", listener.local_addr());
        match &listener {
            BoundListener::Tcp(l) => {
                l.set_nonblocking(true)?;
                accept_loop(svc, net, || match l.accept() {
                    Ok((stream, peer)) => Ok(Some((stream, peer.to_string()))),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                    Err(e) => Err(e),
                })
            }
            #[cfg(unix)]
            BoundListener::Unix(l, path) => {
                l.set_nonblocking(true)?;
                let peer = format!("unix:{}", path.display());
                accept_loop(svc, net, || match l.accept() {
                    Ok((stream, _)) => Ok(Some((stream, peer.clone()))),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                    Err(e) => Err(e),
                })
            }
        }
    })
}

/// Accept until drain: admit up to the connection cap, spawn one protocol
/// thread per client, reject the rest with a structured line.  Scoped
/// threads guarantee every connection is joined before the front returns —
/// the drain barrier the stats tally depends on.
fn accept_loop<S: Conn>(
    svc: &ServiceState<'_>,
    net: &NetOptions,
    mut accept: impl FnMut() -> std::io::Result<Option<(S, String)>>,
) -> Result<()> {
    let active = AtomicUsize::new(0);
    // connection 0 is the stdio transport's identity; sockets start at 1
    let mut next_conn: u64 = 1;
    let mut consecutive_errors = 0usize;
    std::thread::scope(|scope| -> Result<()> {
        loop {
            if svc.draining() {
                log::info!("serve: draining — no longer accepting connections");
                return Ok(());
            }
            let (stream, peer) = match accept() {
                Ok(None) => {
                    std::thread::sleep(POLL_INTERVAL);
                    continue;
                }
                Ok(Some(accepted)) => {
                    consecutive_errors = 0;
                    accepted
                }
                Err(e) => {
                    // transient accept failures (e.g. fd exhaustion) heal;
                    // a listener that only errors is dead — give up loudly
                    consecutive_errors += 1;
                    if consecutive_errors >= 100 {
                        // flag the drain first: live connection threads
                        // must observe it and exit, or the scope join
                        // below this loop would wait on them forever
                        svc.begin_drain();
                        anyhow::bail!(
                            "accept failed {consecutive_errors} times in a row: {e}"
                        );
                    }
                    log::warn!("serve: accept failed ({e}); retrying");
                    std::thread::sleep(POLL_INTERVAL);
                    continue;
                }
            };
            if net.max_connections > 0
                && active.load(Ordering::SeqCst) >= net.max_connections
            {
                reject_connection(svc, stream, net.max_connections, &peer);
                continue;
            }
            let reader = match stream.configure().and_then(|()| stream.split()) {
                Ok(reader) => reader,
                Err(e) => {
                    log::warn!("serve: {peer}: socket setup failed ({e}); dropping");
                    continue;
                }
            };
            let conn = ConnCtx {
                id: next_conn,
                transport: S::TRANSPORT,
                require_hello: true,
            };
            next_conn += 1;
            active.fetch_add(1, Ordering::SeqCst);
            let active = &active;
            scope.spawn(move || {
                let mut stream = stream;
                log::info!("serve: connection {} accepted from {peer}", conn.id);
                if let Err(e) = protocol_loop(svc, &conn, BufReader::new(reader), &mut stream) {
                    log::info!("serve: connection {} dropped: {e:#}", conn.id);
                }
                active.fetch_sub(1, Ordering::SeqCst);
            });
        }
    })
}

/// Over-capacity: answer one structured rejection line and hang up.  Write
/// failures are ignored — the client is being turned away either way.
fn reject_connection<S: Conn>(svc: &ServiceState<'_>, mut stream: S, cap: usize, peer: &str) {
    obs_admission_rejected("connections").inc();
    log::warn!("serve: rejecting {peer}: at the connection cap ({cap})");
    // best-effort blocking mode so the one-line write goes through
    let _ = stream.configure();
    let line = Json::obj(vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::str(format!(
                "server is at its connection capacity ({cap}); retry later"
            )),
        ),
        ("retry_after_ms", Json::num(svc.retry_hint_ms() as f64)),
    ]);
    let _ = writeln!(stream, "{}", line.dump());
    let _ = stream.flush();
}
